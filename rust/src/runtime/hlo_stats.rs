//! Lightweight HLO-text analyzer for the perf pass.
//!
//! Parses the artifact's HLO text (the interchange format) and reports the
//! structural facts the §Perf targets are stated in:
//! * op-kind histogram (how many rng ops per step, dots, fusions, ...);
//! * the largest intermediate tensor (did a full m x n Z materialize more
//!   than necessary?);
//! * **peak temp bytes**: a per-computation liveness scan over the SSA
//!   instruction stream — allocate each non-parameter result at its
//!   definition, free it after its last use — whose maximum live set is the
//!   static peak-temporary footprint. Reported in two flavors:
//!   - `peak_temp_bytes`: every value. Dominated by the forward's own
//!     activation stream (softmax/gelu regions), which both forward forms
//!     share — and, in unoptimized text, by broadcast constants XLA later
//!     fuses away. A coarse upper bound.
//!   - `peak_param_temp_bytes` / `param_temp_total_bytes`: only values
//!     whose result shape matches a (>= 2-D) parameter shape of the same
//!     computation — i.e. materialized perturbed-weight copies and other
//!     weight-shaped machinery. This is the number the implicit
//!     (factor-form) forward is measured by: the materialized `*_loss_pm`
//!     artifacts build dense `W +/- rho Z` copies (4x matrix-param bytes of
//!     temp allocation per two-point call), the `*_loss_pm_implicit` ones
//!     never do.
//!
//! `tezo inspect --hlo <artifact>` prints all of this; the integration
//! tests use [`HloStats::count`] to assert the single-RNG-per-step and
//! fused-update properties and `tests/forward_forms.rs` asserts the
//! param-shaped temp reduction. BENCH_PR5.json records the cross-form
//! numbers (python/bench_forward_forms.py computes them with the mirrored
//! implementation in python/compile/hlo_stats.py — keep both in lockstep).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use anyhow::{Context, Result};

/// Parsed statistics over one HLO module text.
#[derive(Clone, Debug, Default)]
pub struct HloStats {
    /// op name -> occurrences (e.g. "dot", "rng-bit-generator", "fusion")
    pub ops: BTreeMap<String, usize>,
    /// total instruction count
    pub instructions: usize,
    /// largest tensor element count seen in any instruction result shape
    pub largest_tensor: u64,
    /// shape string of that tensor
    pub largest_shape: String,
    /// liveness-scan peak bytes of non-parameter values, max over the
    /// module's computations (the entry computation dominates in practice)
    pub peak_temp_bytes: u64,
    /// liveness-scan peak counting only parameter-shaped values (perturbed
    /// weight copies and other weight-shaped temporaries)
    pub peak_param_temp_bytes: u64,
    /// total bytes of parameter-shaped temporaries allocated per call —
    /// the weight-copy allocation traffic of one two-point evaluation
    pub param_temp_total_bytes: u64,
}

/// One instruction as seen by the liveness scan.
struct ScanInst {
    bytes: u64,
    is_param: bool,
    operands: Vec<String>,
    /// result type without layout (e.g. `f32[64,256]`), for the
    /// parameter-shaped classification; empty for tuple results
    shape: String,
}

impl HloStats {
    /// Parse HLO text.
    pub fn parse(text: &str) -> HloStats {
        let mut stats = HloStats::default();
        // current computation body for the liveness scan: SSA defs in order
        let mut comp: Vec<(String, ScanInst)> = Vec::new();
        for line in text.lines() {
            let t = line.trim_start();
            if t.starts_with('}') {
                // computation ends: fold its liveness peaks into the module's
                stats.fold_computation(&comp);
                comp.clear();
                continue;
            }
            // instruction lines look like (xla_extension 0.5.1 text form):
            //   name.N = f32[64,256]{1,0} op-name(...)
            // optionally prefixed by ROOT or % in other dialects
            let Some(eq) = t.find(" = ") else { continue };
            let lhs = t.get(..eq).unwrap_or("")
                .trim_start_matches("ROOT ").trim_start_matches('%');
            if lhs.is_empty()
                || !lhs.chars().all(|c| c.is_alphanumeric() || ".-_".contains(c))
            {
                continue;
            }
            let Some(rest) = t.get(eq + 3..) else { continue };
            // result type, e.g. f32[64,256]{1,0} or (f32[..], f32[..])
            let (shape_part, after_shape) = match rest.find(' ') {
                Some(sp) => (&rest[..sp], &rest[sp + 1..]),
                None => continue,
            };
            // op name is the token before '('
            let op = after_shape.split('(').next().unwrap_or("").trim();
            if op.is_empty() {
                continue;
            }
            stats.instructions += 1;
            *stats.ops.entry(op.to_string()).or_insert(0) += 1;
            let mut bytes = 0u64;
            for (elems, shape) in parse_shapes(shape_part) {
                bytes += elems * dtype_bytes(&shape);
                if elems > stats.largest_tensor {
                    stats.largest_tensor = elems;
                    stats.largest_shape = shape;
                }
            }
            comp.push((lhs.to_string(), ScanInst {
                bytes,
                is_param: op == "parameter",
                operands: parse_operands(after_shape),
                shape: shape_part.split('{').next().unwrap_or("").to_string(),
            }));
        }
        stats.fold_computation(&comp); // unterminated trailing body, if any
        stats
    }

    /// Fold one computation's liveness peaks into the module stats.
    fn fold_computation(&mut self, comp: &[(String, ScanInst)]) {
        if comp.is_empty() {
            return;
        }
        self.peak_temp_bytes = self.peak_temp_bytes.max(liveness_peak(comp, |_| true));
        // parameter shapes (>= 2-D) of this computation classify which
        // temporaries are weight-shaped
        let param_shapes: std::collections::HashSet<&str> = comp
            .iter()
            .filter(|(_, i)| i.is_param && i.shape.contains(','))
            .map(|(_, i)| i.shape.as_str())
            .collect();
        let is_param_shaped =
            |inst: &ScanInst| param_shapes.contains(inst.shape.as_str());
        self.peak_param_temp_bytes = self
            .peak_param_temp_bytes
            .max(liveness_peak(comp, is_param_shaped));
        self.param_temp_total_bytes += comp
            .iter()
            .filter(|(_, i)| !i.is_param && is_param_shaped(i))
            .map(|(_, i)| i.bytes)
            .sum::<u64>();
    }

    /// Load + parse an artifact file.
    pub fn from_file(path: &Path) -> Result<HloStats> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(Self::parse(&text))
    }

    /// Occurrences of ops whose name contains `needle`.
    pub fn count(&self, needle: &str) -> usize {
        self.ops
            .iter()
            .filter(|(k, _)| k.contains(needle))
            .map(|(_, v)| *v)
            .sum()
    }

    /// Top-k ops by count.
    pub fn top_ops(&self, k: usize) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self.ops.iter()
            .map(|(a, b)| (a.clone(), *b))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.truncate(k);
        v
    }
}

/// Byte width of the dtype prefix of a shape string like `f32[64,256]`.
fn dtype_bytes(shape: &str) -> u64 {
    let dt = shape.split('[').next().unwrap_or("");
    match dt {
        "f64" | "s64" | "u64" | "c64" => 8,
        "f32" | "s32" | "u32" | "i32" => 4,
        "f16" | "bf16" | "s16" | "u16" => 2,
        "pred" | "s8" | "u8" | "s4" | "u4" => 1,
        _ => 4,
    }
}

/// Operand names of one instruction: the identifiers inside the first
/// top-level parenthesis group after the op name (attributes like
/// `kind=kLoop, calls=%fused` sit outside it and are ignored; literal
/// constants inside it do not resolve against the def map, so they drop out
/// of the liveness scan naturally).
fn parse_operands(after_shape: &str) -> Vec<String> {
    let Some(open) = after_shape.find('(') else { return Vec::new() };
    let bytes = after_shape.as_bytes();
    let mut depth = 0usize;
    let mut end = after_shape.len();
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' | b'{' => depth += 1,
            b')' | b'}' => {
                depth -= 1;
                if depth == 0 {
                    end = i;
                    break;
                }
            }
            _ => {}
        }
    }
    let inner = &after_shape[open + 1..end.min(after_shape.len())];
    let mut out = Vec::new();
    let mut d = 0usize;
    let mut start = 0usize;
    let ib = inner.as_bytes();
    for i in 0..=inner.len() {
        let top_comma = i == inner.len()
            || (ib[i] == b',' && d == 0);
        if i < inner.len() {
            match ib[i] {
                b'(' | b'{' | b'[' => d += 1,
                b')' | b'}' | b']' => d = d.saturating_sub(1),
                _ => {}
            }
        }
        if top_comma {
            // tolerate typed operands ("f32[2]{0} %x"): the name is the
            // last whitespace-separated piece
            let tok = inner[start..i].trim();
            let tok = tok.rsplit(' ').next().unwrap_or(tok).trim_start_matches('%');
            let ident: String = tok
                .chars()
                .take_while(|c| c.is_alphanumeric() || ".-_".contains(*c))
                .collect();
            if !ident.is_empty() && ident == tok {
                out.push(ident);
            }
            start = i + 1;
        }
    }
    out
}

/// Peak live bytes over one computation's SSA stream, restricted to
/// non-parameter values satisfying `counts`: allocate each such result at
/// its definition, free it after the instruction that uses it last. Values
/// never used (the root) stay live to the end — they are the computation's
/// output.
fn liveness_peak(comp: &[(String, ScanInst)], counts: impl Fn(&ScanInst) -> bool) -> u64 {
    if comp.is_empty() {
        return 0;
    }
    let index: HashMap<&str, usize> = comp
        .iter()
        .enumerate()
        .map(|(i, (name, _))| (name.as_str(), i))
        .collect();
    // def index -> instruction index of its last use
    let mut last_use: Vec<Option<usize>> = vec![None; comp.len()];
    for (i, (_, inst)) in comp.iter().enumerate() {
        for op in &inst.operands {
            if let Some(&j) = index.get(op.as_str()) {
                last_use[j] = Some(i);
            }
        }
    }
    // frees[i] = defs whose last use is instruction i
    let mut frees: Vec<Vec<usize>> = vec![Vec::new(); comp.len()];
    for (j, lu) in last_use.iter().enumerate() {
        if let Some(i) = lu {
            frees[*i].push(j);
        }
    }
    let mut live = 0u64;
    let mut peak = 0u64;
    for (i, (_, inst)) in comp.iter().enumerate() {
        if !inst.is_param && counts(inst) {
            live += inst.bytes;
            peak = peak.max(live);
        }
        for &j in &frees[i] {
            if !comp[j].1.is_param && counts(&comp[j].1) && j != i {
                live = live.saturating_sub(comp[j].1.bytes);
            }
        }
    }
    peak
}

/// Extract (element_count, shape_string) for every array shape in a result
/// type like `f32[64,256]{1,0}` or `(f32[2], u32[])`.
fn parse_shapes(s: &str) -> Vec<(u64, String)> {
    let mut out = Vec::new();
    let bytes = s.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'[' {
            // find the matching ']'
            if let Some(end) = s[i + 1..].find(']') {
                let dims = &s[i + 1..i + 1 + end];
                let elems: u64 = if dims.is_empty() {
                    1
                } else {
                    dims.split(',')
                        .filter_map(|d| d.trim().parse::<u64>().ok())
                        .product()
                };
                // recover the dtype prefix
                let start = s[..i].rfind(|c: char| !c.is_alphanumeric())
                    .map(|p| p + 1)
                    .unwrap_or(0);
                out.push((elems, format!("{}[{}]", &s[start..i], dims)));
                i += end + 1;
            }
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn

ENTRY main {
  %p0 = f32[64,256]{1,0} parameter(0)
  %p1 = f32[256,64]{1,0} parameter(1)
  %dot = f32[64,64]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}
  %rng = u32[2]{0} rng-bit-generator(%p0), algorithm=rng_default
  ROOT %t = (f32[64,64]{1,0}) tuple(%dot)
}
"#;

    #[test]
    fn parses_ops_and_shapes() {
        let s = HloStats::parse(SAMPLE);
        assert_eq!(s.ops.get("dot"), Some(&1));
        assert_eq!(s.count("rng"), 1);
        assert_eq!(s.ops.get("parameter"), Some(&2));
        assert_eq!(s.largest_tensor, 64 * 256);
    }

    #[test]
    fn scalar_shapes_count_as_one() {
        let shapes = parse_shapes("f32[]");
        assert_eq!(shapes[0].0, 1);
        let shapes = parse_shapes("(f32[2,3], u32[])");
        assert_eq!(shapes[0].0, 6);
        assert_eq!(shapes[1].0, 1);
    }

    #[test]
    fn dtype_widths() {
        assert_eq!(dtype_bytes("f32[4]"), 4);
        assert_eq!(dtype_bytes("f64[4]"), 8);
        assert_eq!(dtype_bytes("bf16[4]"), 2);
        assert_eq!(dtype_bytes("pred[4]"), 1);
    }

    #[test]
    fn operand_parsing_ignores_attributes_and_literals() {
        let ops = parse_operands("dot(%a.1, %b.2), lhs_contracting_dims={1}");
        assert_eq!(ops, vec!["a.1", "b.2"]);
        let ops = parse_operands("fusion(%x), kind=kLoop, calls=%fused_computation");
        assert_eq!(ops, vec!["x"]);
        let ops = parse_operands("constant(0.5)");
        assert_eq!(ops, vec!["0.5"]); // drops out against the def map
        let ops = parse_operands("add(f32[2]{0} %p, f32[2]{0} %q)");
        assert_eq!(ops, vec!["p", "q"]);
    }

    // A module where a big temp dies immediately (t1) and a same-sized temp
    // is defined later: peak must be ONE big temp + the small live values,
    // not two big temps — that is exactly the materialized-vs-implicit
    // distinction the scan exists to measure.
    const LIVENESS: &str = r#"
ENTRY main {
  %p0 = f32[1000]{0} parameter(0)
  %t1 = f32[1000]{0} add(%p0, %p0)
  %s1 = f32[] reduce(%t1, %p0), dimensions={0}
  %t2 = f32[1000]{0} multiply(%p0, %p0)
  %s2 = f32[] reduce(%t2, %p0), dimensions={0}
  ROOT %out = f32[] add(%s1, %s2)
}
"#;

    #[test]
    fn liveness_peak_frees_dead_temps() {
        let s = HloStats::parse(LIVENESS);
        // t1 dies at its last use (%s1), so t2 never coexists with it: the
        // high-water mark is t2 + the two scalars (4008 B), not 2 x 4000 B
        assert_eq!(s.peak_temp_bytes, 4008);
    }

    const LIVENESS_BOTH: &str = r#"
ENTRY main {
  %p0 = f32[1000]{0} parameter(0)
  %t1 = f32[1000]{0} add(%p0, %p0)
  %t2 = f32[1000]{0} multiply(%p0, %p0)
  ROOT %out = f32[1000]{0} add(%t1, %t2)
}
"#;

    #[test]
    fn liveness_peak_counts_simultaneously_live_temps() {
        let s = HloStats::parse(LIVENESS_BOTH);
        // t1, t2, out all live at the root: 3 x 4000 B
        assert_eq!(s.peak_temp_bytes, 12000);
    }

    // A (64,256)-shaped parameter exists, so the (64,256) add is a
    // parameter-shaped temp (a "perturbed weight copy"); the (64,)-shaped
    // add is not.
    const PARAM_SHAPED: &str = r#"
ENTRY main {
  %w = f32[64,256]{1,0} parameter(0)
  %b = f32[64]{0} parameter(1)
  %wp = f32[64,256]{1,0} add(%w, %w)
  %bp = f32[64]{0} add(%b, %b)
  %wp2 = f32[64,256]{1,0} multiply(%wp, %wp)
  ROOT %s = f32[] reduce(%wp2, %bp), dimensions={0,1}
}
"#;

    #[test]
    fn param_shaped_temps_are_classified() {
        let s = HloStats::parse(PARAM_SHAPED);
        // wp + wp2 are weight-shaped temps; wp dies when wp2 is made, but
        // both are briefly live at %wp2
        assert_eq!(s.param_temp_total_bytes, 2 * 64 * 256 * 4);
        assert_eq!(s.peak_param_temp_bytes, 2 * 64 * 256 * 4);
        // the 1-D add never counts
        assert!(s.peak_temp_bytes >= s.peak_param_temp_bytes);
    }

    #[test]
    fn no_param_shaped_temps_in_liveness_sample() {
        // LIVENESS's params are 1-D: nothing classifies as weight-shaped
        let s = HloStats::parse(LIVENESS);
        assert_eq!(s.param_temp_total_bytes, 0);
        assert_eq!(s.peak_param_temp_bytes, 0);
    }

    #[test]
    fn parameters_are_not_temps() {
        let s = HloStats::parse(SAMPLE);
        // SAMPLE's temps: dot 64*64*4 + rng 2*4 + tuple 64*64*4
        assert!(s.peak_temp_bytes >= 64 * 64 * 4);
        assert!(s.peak_temp_bytes < 2 * 64 * 256 * 4,
                "parameter buffers must not count: {}", s.peak_temp_bytes);
    }
}
