//! Shape-aware forward-form autotuner.
//!
//! BENCH_PR5 measured the implicit factor-form forward winning at tiny
//! (1.25x) and *losing* at small (0.86x) on CPU — which form is faster is
//! a property of the (artifact dir, shape, method) triple, not a global
//! constant. This module owns that decision: under `--forward-form auto`
//! (the default) the caller measures both compiled forms with interleaved
//! timed pairs and the winner is pinned in `tuning.json` next to the
//! manifest, so the measurement cost amortizes across runs. The table is
//! versioned and keyed by a manifest fingerprint + shape key; any mismatch
//! invalidates it (stale decisions are never trusted).
//!
//! Layering: measurement needs a driver + parameters + a batch, which live
//! above the runtime — so the timed probe is injected as a closure
//! (`FnMut(ForwardForm) -> Result<u64>` nanoseconds per two-point
//! forward). `coordinator::autotune` supplies the real probe; tests inject
//! fixed timings, which also makes the winner deterministic under
//! `TestClock`. See docs/runtime.md "Autotuning".

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{FormPolicy, ForwardForm, Method};
use crate::jsonx::{self, Value};
use crate::telemetry::Telemetry;

use super::manifest::Manifest;

/// File name of the persisted table, next to `manifest.json`.
pub const TUNING_FILE: &str = "tuning.json";

/// Schema version; a table written by a different version is discarded.
pub const TUNING_VERSION: i64 = 1;

/// Timed interleaved (materialize, implicit) pairs per decision.
pub const TUNE_TRIALS: u64 = 3;

/// One persisted decision: the winning form for `method` on this artifact
/// dir, plus the evidence (best-of-trials ns per form).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TuneEntry {
    /// winning loss artifact name (what the drivers will dispatch)
    pub artifact: String,
    pub form: ForwardForm,
    /// best-of-trials two-point forward, nanoseconds
    pub materialize_ns: u64,
    pub implicit_ns: u64,
    pub trials: u64,
}

/// The persisted per-artifact-dir tuning table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TuningTable {
    /// FNV-1a-64 of the manifest.json bytes (hex)
    pub manifest_hash: String,
    /// shape key of the config the decisions were measured on
    pub shape: String,
    /// method name -> decision
    pub entries: BTreeMap<String, TuneEntry>,
}

/// Where a run's concrete form came from (reported in the `tuning` block
/// of `TrainOutcome.summary_json` and the PR description).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneSource {
    /// `--forward-form` pinned it explicitly; no table involved
    Pinned,
    /// the manifest ships only one lowering for this method — nothing to
    /// choose between (MeZO family, SubZO, ZO-AdaMU, FO, old manifests)
    Inert,
    /// a valid `tuning.json` already held the decision
    CacheHit,
    /// both forms were measured this run and the winner was persisted
    Measured,
    /// no table and no way to measure here; the documented Auto fallback
    Fallback,
}

impl TuneSource {
    pub fn name(&self) -> &'static str {
        match self {
            TuneSource::Pinned => "pinned",
            TuneSource::Inert => "inert",
            TuneSource::CacheHit => "cache_hit",
            TuneSource::Measured => "measured",
            TuneSource::Fallback => "fallback",
        }
    }
}

/// A resolved form plus provenance and (when measured or cached) the
/// per-form evidence.
#[derive(Clone, Debug, PartialEq)]
pub struct Resolution {
    pub form: ForwardForm,
    pub source: TuneSource,
    pub materialize_ns: Option<u64>,
    pub implicit_ns: Option<u64>,
    pub trials: u64,
}

impl Resolution {
    fn bare(form: ForwardForm, source: TuneSource) -> Resolution {
        Resolution { form, source, materialize_ns: None, implicit_ns: None,
                     trials: 0 }
    }

    /// The `tuning` block of `TrainOutcome.summary_json`.
    pub fn summary_json(&self) -> Value {
        let ns = |v: Option<u64>| match v {
            Some(n) => Value::i(n as i64),
            None => Value::Null,
        };
        Value::obj(vec![
            ("form", Value::str(self.form.name())),
            ("source", Value::str(self.source.name())),
            ("materialize_ns", ns(self.materialize_ns)),
            ("implicit_ns", ns(self.implicit_ns)),
            ("trials", Value::i(self.trials as i64)),
        ])
    }
}

/// FNV-1a-64 of the manifest.json bytes, as 16 hex digits. Any rebuild of
/// the artifacts (new HLO hashes, new tiles, new shapes) changes the
/// manifest text and therefore the fingerprint.
pub fn manifest_fingerprint(dir: &Path) -> Result<String> {
    let bytes = std::fs::read(dir.join("manifest.json"))
        .with_context(|| format!("reading {}/manifest.json for the tuning \
                                  fingerprint", dir.display()))?;
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    Ok(format!("{h:016x}"))
}

/// Shape key a decision is valid for: the geometry the forward actually
/// depends on. Eval-set size, lr, seeds etc. deliberately excluded.
pub fn shape_key(m: &Manifest) -> String {
    let c = &m.config;
    format!("b{}s{}d{}L{}v{}", c.batch, c.seq_len, c.d_model, c.n_layers,
            c.vocab)
}

impl TuningTable {
    pub fn new(manifest_hash: String, shape: String) -> TuningTable {
        TuningTable { manifest_hash, shape, entries: BTreeMap::new() }
    }

    pub fn path(dir: &Path) -> PathBuf {
        dir.join(TUNING_FILE)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("version", Value::i(TUNING_VERSION)),
            ("manifest_hash", Value::str(&self.manifest_hash)),
            ("shape", Value::str(&self.shape)),
            ("entries", Value::Object(
                self.entries
                    .iter()
                    .map(|(k, e)| (k.clone(), Value::obj(vec![
                        ("artifact", Value::str(&e.artifact)),
                        ("form", Value::str(e.form.name())),
                        ("materialize_ns", Value::i(e.materialize_ns as i64)),
                        ("implicit_ns", Value::i(e.implicit_ns as i64)),
                        ("trials", Value::i(e.trials as i64)),
                    ])))
                    .collect(),
            )),
        ])
    }

    /// Parse a table; errors on schema problems, but a *version* mismatch
    /// is also an error here (callers treating staleness as a miss use
    /// [`TuningTable::load`]).
    pub fn from_json(v: &Value) -> Result<TuningTable> {
        let version = v.get("version")?.as_i64()?;
        if version != TUNING_VERSION {
            anyhow::bail!("tuning table version {version} (want \
                           {TUNING_VERSION})");
        }
        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_object()? {
            let ns = |k: &str| -> Result<u64> {
                Ok(e.get(k)?.as_i64()?.max(0) as u64)
            };
            entries.insert(name.clone(), TuneEntry {
                artifact: e.get_str("artifact")?.to_string(),
                form: ForwardForm::parse(e.get_str("form")?)?,
                materialize_ns: ns("materialize_ns")?,
                implicit_ns: ns("implicit_ns")?,
                trials: ns("trials")?,
            });
        }
        Ok(TuningTable {
            manifest_hash: v.get_str("manifest_hash")?.to_string(),
            shape: v.get_str("shape")?.to_string(),
            entries,
        })
    }

    /// Load the table for `dir` if it exists AND is valid for
    /// (`manifest_hash`, `shape`). A missing, unparseable, version-skewed,
    /// or stale table is a cache miss (`None`), never an error — the next
    /// measurement overwrites it.
    pub fn load(dir: &Path, manifest_hash: &str, shape: &str)
                -> Option<TuningTable> {
        let text = std::fs::read_to_string(Self::path(dir)).ok()?;
        let v = jsonx::parse(&text).ok()?;
        let t = Self::from_json(&v).ok()?;
        if t.manifest_hash != manifest_hash || t.shape != shape {
            return None;
        }
        Some(t)
    }

    /// Persist next to the manifest (atomic + fsynced: a concurrent or
    /// crashed run never observes a half-written table).
    pub fn save(&self, dir: &Path) -> Result<()> {
        let path = Self::path(dir);
        super::durable::write_atomic(&path, jsonx::to_string_pretty(&self.to_json()).as_bytes())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

/// Faster form wins; ties go to the factor form (it also wins on memory,
/// so equal time is not a tie in practice).
pub fn winner(materialize_ns: u64, implicit_ns: u64) -> ForwardForm {
    if materialize_ns < implicit_ns {
        ForwardForm::Materialize
    } else {
        ForwardForm::Implicit
    }
}

/// Does `method` on this manifest actually have two lowerings to choose
/// between? False for the dense-Z families and for artifact dirs built
/// before the implicit artifacts existed.
pub fn tunable(manifest: &Manifest, method: Method) -> bool {
    manifest.loss_artifact(method, ForwardForm::Implicit)
        != manifest.loss_artifact(method, ForwardForm::Materialize)
}

/// Resolve without measurement or table I/O: explicit pins and methods
/// with a single lowering. `None` means a real decision is needed.
pub fn resolve_static(manifest: &Manifest, method: Method,
                      policy: FormPolicy) -> Option<Resolution> {
    if let Some(form) = policy.pinned() {
        return Some(Resolution::bare(form, TuneSource::Pinned));
    }
    if !tunable(manifest, method) {
        // both names dispatch the same artifact; pick the documented
        // fallback so warmup/memmodel see a consistent answer
        return Some(Resolution::bare(policy.resolve_fallback(),
                                     TuneSource::Inert));
    }
    None
}

/// Table lookup (no timing). `Some` is a cache hit — the counter is
/// emitted, and *no* interleaved timing spans are recorded, which is how
/// a warm second run is distinguishable in the trace.
pub fn resolve_cached(manifest: &Manifest, method: Method,
                      tel: &Telemetry) -> Option<Resolution> {
    let hash = manifest_fingerprint(&manifest.dir).ok()?;
    let shape = shape_key(manifest);
    let table = TuningTable::load(&manifest.dir, &hash, &shape)?;
    let e = table.entries.get(method.name())?;
    // a cached decision must still name an artifact the manifest has
    if !manifest.artifacts.contains_key(&e.artifact) {
        return None;
    }
    tel.counter("tune", "cache_hit", 1.0, -1);
    Some(Resolution {
        form: e.form,
        source: TuneSource::CacheHit,
        materialize_ns: Some(e.materialize_ns),
        implicit_ns: Some(e.implicit_ns),
        trials: e.trials,
    })
}

/// Measure both forms via `measure` (ns per two-point forward, called in
/// interleaved (materialize, implicit) pairs so drift hits both equally),
/// pin the best-of-trials winner, and persist the table. Emits the
/// cache-miss counter and one `tune` span per timed call (lane = trial).
pub fn measure_and_pin(
    manifest: &Manifest, method: Method, tel: &Telemetry,
    measure: &mut dyn FnMut(ForwardForm) -> Result<u64>,
) -> Result<Resolution> {
    tel.counter("tune", "cache_miss", 1.0, -1);
    let mut best_m = u64::MAX;
    let mut best_i = u64::MAX;
    for trial in 0..TUNE_TRIALS {
        let m = measure(ForwardForm::Materialize)?;
        tel.span_dur("tune", "materialize", m, trial as u32, -1);
        best_m = best_m.min(m);
        let i = measure(ForwardForm::Implicit)?;
        tel.span_dur("tune", "implicit", i, trial as u32, -1);
        best_i = best_i.min(i);
    }
    let form = winner(best_m, best_i);
    let hash = manifest_fingerprint(&manifest.dir)?;
    let shape = shape_key(manifest);
    // keep other methods' decisions when the table is still valid for
    // this manifest; otherwise start fresh (staleness is per-table)
    let mut table = TuningTable::load(&manifest.dir, &hash, &shape)
        .unwrap_or_else(|| TuningTable::new(hash, shape));
    table.entries.insert(method.name().to_string(), TuneEntry {
        artifact: manifest.loss_artifact(method, form).to_string(),
        form,
        materialize_ns: best_m,
        implicit_ns: best_i,
        trials: TUNE_TRIALS,
    });
    table.save(&manifest.dir)?;
    Ok(Resolution {
        form,
        source: TuneSource::Measured,
        materialize_ns: Some(best_m),
        implicit_ns: Some(best_i),
        trials: TUNE_TRIALS,
    })
}

/// Full resolution: static short-circuits, then the persisted table, then
/// measurement via the injected probe. The one entry point measuring
/// callers need.
pub fn resolve_with(
    manifest: &Manifest, method: Method, policy: FormPolicy, tel: &Telemetry,
    measure: &mut dyn FnMut(ForwardForm) -> Result<u64>,
) -> Result<Resolution> {
    if let Some(r) = resolve_static(manifest, method, policy) {
        return Ok(r);
    }
    if let Some(r) = resolve_cached(manifest, method, tel) {
        return Ok(r);
    }
    measure_and_pin(manifest, method, tel, measure)
}

/// Resolution for contexts that cannot measure (no runtime open, e.g. the
/// memory model or a coordinator that only loaded the manifest): static,
/// then the table, then the documented `Auto` fallback.
pub fn resolve_offline(manifest: &Manifest, method: Method,
                       policy: FormPolicy, tel: &Telemetry) -> Resolution {
    if let Some(r) = resolve_static(manifest, method, policy) {
        return r;
    }
    match resolve_cached(manifest, method, tel) {
        Some(r) => r,
        None => Resolution::bare(policy.resolve_fallback(),
                                 TuneSource::Fallback),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_fixture() -> TuningTable {
        let mut t = TuningTable::new("deadbeefdeadbeef".into(),
                                     "b8s64d64L2v512".into());
        t.entries.insert("tezo".into(), TuneEntry {
            artifact: "tezo_loss_pm".into(),
            form: ForwardForm::Materialize,
            materialize_ns: 1_000,
            implicit_ns: 2_000,
            trials: 3,
        });
        t
    }

    #[test]
    fn table_json_roundtrip() {
        let t = table_fixture();
        let text = jsonx::to_string_pretty(&t.to_json());
        let back = TuningTable::from_json(&jsonx::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn version_skew_rejected() {
        let mut v = table_fixture().to_json();
        if let Value::Object(kv) = &mut v {
            for (k, val) in kv.iter_mut() {
                if k == "version" {
                    *val = Value::i(TUNING_VERSION + 1);
                }
            }
        }
        assert!(TuningTable::from_json(&v).is_err());
    }

    #[test]
    fn load_rejects_stale_tables() {
        let dir = std::env::temp_dir()
            .join(format!("tezo-tune-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let t = table_fixture();
        t.save(&dir).unwrap();
        assert_eq!(TuningTable::load(&dir, "deadbeefdeadbeef",
                                     "b8s64d64L2v512"),
                   Some(t));
        // hash mismatch and shape mismatch are both cache misses
        assert!(TuningTable::load(&dir, "0000000000000000",
                                  "b8s64d64L2v512").is_none());
        assert!(TuningTable::load(&dir, "deadbeefdeadbeef",
                                  "b1s8d8L1v64").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn winner_ties_to_implicit() {
        assert_eq!(winner(999, 1000), ForwardForm::Materialize);
        assert_eq!(winner(1000, 999), ForwardForm::Implicit);
        assert_eq!(winner(1000, 1000), ForwardForm::Implicit);
    }

    #[test]
    fn summary_json_shape() {
        let r = Resolution {
            form: ForwardForm::Materialize,
            source: TuneSource::Measured,
            materialize_ns: Some(10),
            implicit_ns: Some(20),
            trials: 3,
        };
        let v = r.summary_json();
        assert_eq!(v.get_str("form").unwrap(), "materialize");
        assert_eq!(v.get_str("source").unwrap(), "measured");
        assert_eq!(v.get("materialize_ns").unwrap().as_i64().unwrap(), 10);
        // unresolved evidence serializes as null, not 0
        let bare = Resolution::bare(ForwardForm::Implicit, TuneSource::Pinned);
        assert!(matches!(bare.summary_json().get("implicit_ns").unwrap(),
                         Value::Null));
    }
}
