//! PJRT client + executable/plan caches + the staging pool.
//!
//! One [`Runtime`] per artifact directory. Executables compile lazily on
//! first use and are cached for the life of the process (XLA:CPU compile of
//! the bigger step functions takes seconds — the cache is what makes the
//! steady-state hot loop pure execution). [`CallPlan`]s resolve the same
//! way: once per artifact, cached forever, so steady-state dispatch never
//! re-walks the manifest. [`Runtime::warmup`] front-loads both for a known
//! artifact set (see [`Manifest::method_artifacts`]) so first-step latency
//! does not depend on which artifact happens to run first.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::{ForwardForm, Method};
use crate::telemetry::Stopwatch;

use super::manifest::Manifest;
use super::plan::CallPlan;
use super::stage::{DeviceStage, StepArena};

/// Runtime = PJRT CPU client + manifest + compiled-executable cache +
/// resolved-plan cache + the persistent device staging pool.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    plans: RefCell<HashMap<String, Rc<CallPlan>>>,
    stage: DeviceStage,
    /// cumulative compile seconds (reported by `tezo inspect`)
    compile_secs: RefCell<f64>,
}

impl Runtime {
    /// Open the artifact directory for one model config.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            plans: RefCell::new(HashMap::new()),
            stage: DeviceStage::new(),
            compile_secs: RefCell::new(0.0),
        })
    }

    /// Open by config name under the default artifacts root.
    pub fn open_config(config: &str) -> Result<Runtime> {
        Self::open(&crate::artifacts_root().join(config))
    }

    /// Get (compiling if needed) the executable for `artifact`.
    pub fn executable(&self, artifact: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(artifact) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.artifact(artifact)?;
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {artifact}"))?,
        );
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(artifact.to_string(), exe.clone());
        Ok(exe)
    }

    /// Get (resolving once if needed) the call plan for `artifact`.
    pub fn plan(&self, artifact: &str) -> Result<Rc<CallPlan>> {
        if let Some(plan) = self.plans.borrow().get(artifact) {
            return Ok(plan.clone());
        }
        let meta = self.manifest.artifact(artifact)?;
        let plan = Rc::new(CallPlan::new(artifact, meta)?);
        self.plans.borrow_mut().insert(artifact.to_string(), plan.clone());
        Ok(plan)
    }

    /// The persistent staging pool.
    pub fn stage(&self) -> &DeviceStage {
        &self.stage
    }

    /// Staging arena scoped to training step `step` (advances the pool's
    /// eviction horizon).
    pub fn step_arena(&self, step: u64) -> StepArena<'_> {
        self.stage.step_arena(&self.client, step)
    }

    /// Staging arena whose entries stay resident for the life of the
    /// runtime (eval sets, run-constant tensors).
    pub fn persistent_arena(&self) -> StepArena<'_> {
        self.stage.persistent_arena(&self.client)
    }

    /// Pre-resolve plans and pre-compile executables for a set of
    /// artifacts (so the training loop starts hot).
    pub fn warmup(&self, artifacts: &[&str]) -> Result<()> {
        for a in artifacts {
            self.plan(a)?;
            self.executable(a)?;
        }
        Ok(())
    }

    /// Warm up exactly the artifact set `method` dispatches during
    /// training under `form` (see [`Manifest::method_artifacts`]).
    pub fn warmup_method(&self, method: Method, form: ForwardForm) -> Result<()> {
        self.warmup(&self.manifest.method_artifacts(method, form)?)
    }

    pub fn compile_seconds(&self) -> f64 {
        *self.compile_secs.borrow()
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
