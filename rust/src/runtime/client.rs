//! PJRT client + executable cache.
//!
//! One [`Runtime`] per artifact directory. Executables compile lazily on
//! first use and are cached for the life of the process (XLA:CPU compile of
//! the bigger step functions takes seconds — the cache is what makes the
//! steady-state hot loop pure execution).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::Manifest;

/// Runtime = PJRT CPU client + manifest + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative compile seconds (reported by `tezo inspect`)
    compile_secs: RefCell<f64>,
}

impl Runtime {
    /// Open the artifact directory for one model config.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_secs: RefCell::new(0.0),
        })
    }

    /// Open by config name under the default artifacts root.
    pub fn open_config(config: &str) -> Result<Runtime> {
        Self::open(&crate::artifacts_root().join(config))
    }

    /// Get (compiling if needed) the executable for `artifact`.
    pub fn executable(&self, artifact: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(artifact) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.artifact(artifact)?;
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {artifact}"))?,
        );
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(artifact.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (so the training loop starts hot).
    pub fn warmup(&self, artifacts: &[&str]) -> Result<()> {
        for a in artifacts {
            self.executable(a)?;
        }
        Ok(())
    }

    pub fn compile_seconds(&self) -> f64 {
        *self.compile_secs.borrow()
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
