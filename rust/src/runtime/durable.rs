//! Durable IO: the one module allowed to create and write files on the
//! hot path (enforced by lint rule `TZ-IO001`, see docs/invariants.md).
//!
//! Everything that must survive a crash — checkpoints, the step journal,
//! the tuning table — funnels through two primitives:
//!
//! * [`write_atomic`]: same-directory temp file + fsync + atomic rename.
//!   A crash at any point leaves either the old file or the new file,
//!   never a torn mix.
//! * [`append_sync`]: append bytes to an open log and fsync before
//!   returning. A crash leaves at most one torn tail, which the journal's
//!   framing detects and truncates on recovery.
//!
//! The module also hosts the fault-injection seam ([`failpoint`]) the
//! robustness test battery uses to simulate full disks, torn writes, and
//! crash-after-rename without an actual kill -9 — see docs/robustness.md.

use std::fs::File;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Injectable IO failures for the robustness tests. Failpoints are
/// thread-local (tests run against their own temp dirs on their own
/// threads) and disarm after firing once, except the post-crash state of
/// [`Failure::CrashAfterRename`], which poisons every subsequent durable
/// op until [`failpoint::reset`] — modeling a process that died right
/// after the rename syscall was made durable.
pub mod failpoint {
    use std::cell::Cell;

    /// The failure the next matching durable op should exhibit.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum Failure {
        /// the write fails before any byte reaches the target (full disk)
        Enospc,
        /// only the first `keep` bytes land, then the op errors (torn write)
        Torn { keep: usize },
        /// the rename completes durably, then the process "dies": the op
        /// errors and every later durable op errors until `reset`
        CrashAfterRename,
    }

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub(super) enum State {
        Idle,
        Armed(Failure),
        Crashed,
    }

    thread_local! {
        static STATE: Cell<State> = const { Cell::new(State::Idle) };
    }

    /// Arm `f` for the next durable op on this thread.
    pub fn arm(f: Failure) {
        STATE.with(|s| s.set(State::Armed(f)));
    }

    /// Disarm any pending failure and clear the post-crash poison.
    pub fn reset() {
        STATE.with(|s| s.set(State::Idle));
    }

    /// Consume the armed failure, if any. The crashed state is sticky.
    pub(super) fn take() -> State {
        STATE.with(|s| {
            let cur = s.get();
            match cur {
                State::Armed(_) => s.set(State::Idle),
                State::Idle | State::Crashed => {}
            }
            cur
        })
    }

    pub(super) fn crash() {
        STATE.with(|s| s.set(State::Crashed));
    }
}

use failpoint::{Failure, State};

fn check_crashed() -> Result<State> {
    let st = failpoint::take();
    if st == State::Crashed {
        anyhow::bail!("failpoint: process crashed (durable IO poisoned until reset)");
    }
    Ok(st)
}

/// Write `bytes` to `path` via a same-directory temp file + fsync + rename
/// (rename within one directory is atomic on POSIX filesystems). A crash
/// at any point leaves either the previous file or the complete new one.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let st = check_crashed()?;
    let mut name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("no file name in {}", path.display()))?
        .to_os_string();
    name.push(".tmp");
    let tmp = path.with_file_name(name);
    let mut f = File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    match st {
        State::Armed(Failure::Enospc) => {
            anyhow::bail!("failpoint: ENOSPC writing {}", tmp.display());
        }
        State::Armed(Failure::Torn { keep }) => {
            let keep = keep.min(bytes.len());
            // a torn temp write: partial bytes land, the rename never runs,
            // so the target file is untouched
            f.write_all(bytes.get(..keep).unwrap_or(bytes))
                .with_context(|| format!("writing {}", tmp.display()))?;
            let _ = f.sync_all();
            anyhow::bail!("failpoint: torn write of {} ({} of {} bytes)",
                          tmp.display(), keep, bytes.len());
        }
        State::Armed(Failure::CrashAfterRename) | State::Idle | State::Crashed => {}
    }
    f.write_all(bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all()
        .with_context(|| format!("syncing {}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    if st == State::Armed(Failure::CrashAfterRename) {
        failpoint::crash();
        anyhow::bail!("failpoint: crashed after renaming {}", path.display());
    }
    Ok(())
}

/// Best-effort directory fsync, persisting the renames committed inside it
/// (unix-specific; a no-op where directories cannot be opened).
pub fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Open `path` for appending (created if missing).
pub fn open_append(path: &Path) -> Result<File> {
    std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening {} for append", path.display()))
}

/// Append `bytes` to an open log file and fsync before returning. Under a
/// torn-write failpoint only a prefix lands — exactly the torn tail the
/// journal's frame recovery must truncate.
pub fn append_sync(f: &mut File, bytes: &[u8]) -> Result<()> {
    let st = check_crashed()?;
    match st {
        State::Armed(Failure::Enospc) => {
            anyhow::bail!("failpoint: ENOSPC on append");
        }
        State::Armed(Failure::Torn { keep }) => {
            let keep = keep.min(bytes.len());
            f.write_all(bytes.get(..keep).unwrap_or(bytes))
                .context("appending (torn)")?;
            let _ = f.sync_all();
            anyhow::bail!("failpoint: torn append ({} of {} bytes)", keep, bytes.len());
        }
        State::Armed(Failure::CrashAfterRename) | State::Idle | State::Crashed => {}
    }
    f.write_all(bytes).context("appending")?;
    f.sync_all().context("syncing append")?;
    if st == State::Armed(Failure::CrashAfterRename) {
        failpoint::crash();
        anyhow::bail!("failpoint: crashed after append was made durable");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tezo_durable_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_atomic_replaces_whole_file() {
        let d = tmp("atomic");
        let p = d.join("x.bin");
        write_atomic(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        write_atomic(&p, b"second-longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second-longer");
    }

    #[test]
    fn enospc_failpoint_leaves_target_untouched() {
        let d = tmp("enospc");
        let p = d.join("x.bin");
        write_atomic(&p, b"good").unwrap();
        failpoint::arm(failpoint::Failure::Enospc);
        assert!(write_atomic(&p, b"bad").is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"good");
        // failpoint disarmed after one shot
        write_atomic(&p, b"better").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"better");
    }

    #[test]
    fn torn_failpoint_never_renames() {
        let d = tmp("torn");
        let p = d.join("x.bin");
        write_atomic(&p, b"good").unwrap();
        failpoint::arm(failpoint::Failure::Torn { keep: 2 });
        assert!(write_atomic(&p, b"bad-data").is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"good");
    }

    #[test]
    fn crash_after_rename_commits_then_poisons() {
        let d = tmp("crash");
        let p = d.join("x.bin");
        failpoint::arm(failpoint::Failure::CrashAfterRename);
        assert!(write_atomic(&p, b"committed").is_err());
        // the rename itself went through...
        assert_eq!(std::fs::read(&p).unwrap(), b"committed");
        // ...and everything after the "crash" fails until reset
        assert!(write_atomic(&d.join("y.bin"), b"z").is_err());
        failpoint::reset();
        write_atomic(&d.join("y.bin"), b"z").unwrap();
    }

    #[test]
    fn append_sync_appends_and_torn_keeps_prefix() {
        let d = tmp("append");
        let p = d.join("log.bin");
        let mut f = open_append(&p).unwrap();
        append_sync(&mut f, b"aaaa").unwrap();
        append_sync(&mut f, b"bbbb").unwrap();
        failpoint::arm(failpoint::Failure::Torn { keep: 1 });
        assert!(append_sync(&mut f, b"cccc").is_err());
        failpoint::reset();
        assert_eq!(std::fs::read(&p).unwrap(), b"aaaabbbbc");
    }
}
