//! Device-resident parameter store.
//!
//! Parameters are `PjRtBuffer`s for their whole life: loaded once from the
//! AOT `.bin` files, passed to every artifact call by reference, and
//! *swapped* (not copied) when an update artifact returns the new tensors.
//! Host copies only happen for analysis (`fetch`) — never on the step path.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::manifest::Manifest;
use crate::tensor::Matrix;

/// All model parameters, in manifest order.
pub struct ParamStore {
    pub entries: Vec<super::manifest::ParamEntry>,
    bufs: Vec<xla::PjRtBuffer>,
}

impl ParamStore {
    /// Load the initial parameters shipped with the artifacts.
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest) -> Result<ParamStore> {
        let mut bufs = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let path = manifest.dir.join(&p.bin);
            let host = read_f32_bin(&path, p.numel())?;
            let buf = client
                .buffer_from_host_buffer(&host, &p.shape, None)
                .with_context(|| format!("uploading {}", p.name))?;
            bufs.push(buf);
        }
        Ok(ParamStore { entries: manifest.params.clone(), bufs })
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Buffer of parameter `i` (manifest order).
    pub fn buf(&self, i: usize) -> &xla::PjRtBuffer {
        debug_assert!(i < self.bufs.len(), "param index {i} out of range");
        &self.bufs[i]
    }

    pub fn bufs(&self) -> &[xla::PjRtBuffer] {
        &self.bufs
    }

    /// Index of a named parameter.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.entries
            .iter()
            .position(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown param {name:?}"))
    }

    /// Swap in updated parameter buffers (from an update artifact's outputs).
    /// `new` must be exactly one buffer per parameter, manifest order.
    pub fn replace_all(&mut self, new: Vec<xla::PjRtBuffer>) -> Result<()> {
        ensure!(new.len() == self.bufs.len(),
                "replace_all: {} buffers for {} params", new.len(), self.bufs.len());
        self.bufs = new;
        Ok(())
    }

    /// Host copy of one parameter (analysis path).
    pub fn fetch(&self, i: usize) -> Result<Vec<f32>> {
        let buf = self
            .bufs
            .get(i)
            .ok_or_else(|| anyhow::anyhow!("param index {i} out of range"))?;
        let lit = buf.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Host copy of a named 2D parameter as a [`Matrix`].
    pub fn fetch_matrix(&self, name: &str) -> Result<Matrix> {
        let i = self.index_of(name)?;
        let e = &self.entries[i];
        ensure!(e.shape.len() == 2, "{name} is not 2D");
        Matrix::from_vec(e.shape[0], e.shape[1], self.fetch(i)?)
    }

    /// Total parameter elements.
    pub fn numel(&self) -> usize {
        self.entries.iter().map(|e| e.numel()).sum()
    }
}

/// Read a raw little-endian f32 file of exactly `numel` values.
pub fn read_f32_bin(path: &Path, numel: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    ensure!(bytes.len() == numel * 4,
            "{}: {} bytes, expected {}", path.display(), bytes.len(), numel * 4);
    Ok(f32_from_le_bytes(&bytes))
}

/// Bulk little-endian bytes → f32 decode (inverse of [`f32_le_bytes`]).
/// Writes into a pre-sized buffer through a zipped iterator so the loop
/// carries no per-element capacity/branch work — the multi-hundred-MB
/// parameter and checkpoint loads go through here.
pub fn f32_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; bytes.len() / 4];
    for (x, src) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *x = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
    }
    out
}

/// Bulk little-endian byte image of an f32 slice (the checkpoint-save
/// path; kept beside its inverse so the formats cannot drift).
pub fn f32_le_bytes(host: &[f32]) -> Vec<u8> {
    let mut bytes = vec![0u8; host.len() * 4];
    for (dst, x) in bytes.chunks_exact_mut(4).zip(host) {
        dst.copy_from_slice(&x.to_le_bytes());
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn le_bytes_roundtrip_is_exact() {
        let vals = [0.0f32, -0.0, 1.5, -3.25e-7, f32::MAX, f32::MIN_POSITIVE,
                    f32::INFINITY, f32::NEG_INFINITY];
        let bytes = f32_le_bytes(&vals);
        assert_eq!(bytes.len(), vals.len() * 4);
        let back = f32_from_le_bytes(&bytes);
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // NaN payloads survive bit-exactly too
        let nan = f32::from_bits(0x7FC0_1234);
        assert_eq!(f32_from_le_bytes(&f32_le_bytes(&[nan]))[0].to_bits(),
                   nan.to_bits());
    }

    #[test]
    fn empty_and_truncated_inputs() {
        assert!(f32_from_le_bytes(&[]).is_empty());
        // trailing partial word is ignored by chunks_exact (read_f32_bin
        // guards exact sizes before decoding)
        assert_eq!(f32_from_le_bytes(&[0, 0, 128, 63, 9]), vec![1.0f32]);
    }
}
