//! Device-resident parameter store.
//!
//! Parameters are `PjRtBuffer`s for their whole life: loaded once from the
//! AOT `.bin` files, passed to every artifact call by reference, and
//! *swapped* (not copied) when an update artifact returns the new tensors.
//! Host copies only happen for analysis (`fetch`) — never on the step path.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::manifest::Manifest;
use crate::tensor::Matrix;

/// All model parameters, in manifest order.
pub struct ParamStore {
    pub entries: Vec<super::manifest::ParamEntry>,
    bufs: Vec<xla::PjRtBuffer>,
}

impl ParamStore {
    /// Load the initial parameters shipped with the artifacts.
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest) -> Result<ParamStore> {
        let mut bufs = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let path = manifest.dir.join(&p.bin);
            let host = read_f32_bin(&path, p.numel())?;
            let buf = client
                .buffer_from_host_buffer(&host, &p.shape, None)
                .with_context(|| format!("uploading {}", p.name))?;
            bufs.push(buf);
        }
        Ok(ParamStore { entries: manifest.params.clone(), bufs })
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Buffer of parameter `i` (manifest order).
    pub fn buf(&self, i: usize) -> &xla::PjRtBuffer {
        &self.bufs[i]
    }

    pub fn bufs(&self) -> &[xla::PjRtBuffer] {
        &self.bufs
    }

    /// Index of a named parameter.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.entries
            .iter()
            .position(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown param {name:?}"))
    }

    /// Swap in updated parameter buffers (from an update artifact's outputs).
    /// `new` must be exactly one buffer per parameter, manifest order.
    pub fn replace_all(&mut self, new: Vec<xla::PjRtBuffer>) -> Result<()> {
        ensure!(new.len() == self.bufs.len(),
                "replace_all: {} buffers for {} params", new.len(), self.bufs.len());
        self.bufs = new;
        Ok(())
    }

    /// Host copy of one parameter (analysis path).
    pub fn fetch(&self, i: usize) -> Result<Vec<f32>> {
        let lit = self.bufs[i].to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Host copy of a named 2D parameter as a [`Matrix`].
    pub fn fetch_matrix(&self, name: &str) -> Result<Matrix> {
        let i = self.index_of(name)?;
        let e = &self.entries[i];
        ensure!(e.shape.len() == 2, "{name} is not 2D");
        Matrix::from_vec(e.shape[0], e.shape[1], self.fetch(i)?)
    }

    /// Total parameter elements.
    pub fn numel(&self) -> usize {
        self.entries.iter().map(|e| e.numel()).sum()
    }
}

/// Read a raw little-endian f32 file of exactly `numel` values.
pub fn read_f32_bin(path: &Path, numel: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    ensure!(bytes.len() == numel * 4,
            "{}: {} bytes, expected {}", path.display(), bytes.len(), numel * 4);
    let mut out = Vec::with_capacity(numel);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}
