//! CLI parsing substrate (the offline registry has no `clap`).
//!
//! Declarative-ish parser: commands register flags with [`ArgSpec`]s, the
//! parser handles `--flag value`, `--flag=value`, boolean switches,
//! defaults, required checks, and renders `--help` text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// One flag specification.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub required: bool,
    pub is_switch: bool,
}

impl ArgSpec {
    pub const fn opt(name: &'static str, default: &'static str, help: &'static str) -> Self {
        Self { name, help, default: Some(default), required: false, is_switch: false }
    }

    pub const fn req(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: true, is_switch: false }
    }

    pub const fn switch(name: &'static str, help: &'static str) -> Self {
        Self { name, help, default: None, required: false, is_switch: true }
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing --{name}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get_str(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get_str(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get_str(name)?
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_f32(&self, name: &str) -> Result<f32> {
        Ok(self.get_f64(name)? as f32)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Comma-separated list value.
    pub fn get_list(&self, name: &str) -> Result<Vec<String>> {
        Ok(self
            .get_str(name)?
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect())
    }
}

/// Parse `argv` (after the subcommand) against `specs`.
pub fn parse(argv: &[String], specs: &[ArgSpec]) -> Result<Args> {
    let mut out = Args::default();
    for spec in specs {
        if let Some(d) = spec.default {
            out.values.insert(spec.name.to_string(), d.to_string());
        }
    }
    let find = |name: &str| -> Result<&ArgSpec> {
        specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("unknown flag --{name}"))
    };
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(rest) = tok.strip_prefix("--") {
            let (name, inline) = match rest.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (rest, None),
            };
            let spec = find(name)?;
            if spec.is_switch {
                if inline.is_some() {
                    bail!("--{name} is a switch and takes no value");
                }
                out.switches.push(name.to_string());
            } else {
                let value = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| anyhow!("--{name} expects a value"))?
                    }
                };
                out.values.insert(name.to_string(), value);
            }
        } else {
            out.positional.push(tok.clone());
        }
        i += 1;
    }
    for spec in specs {
        if spec.required && out.get(spec.name).is_none() {
            bail!("missing required flag --{}", spec.name);
        }
    }
    Ok(out)
}

/// Render help text for a command.
pub fn render_help(cmd: &str, about: &str, specs: &[ArgSpec]) -> String {
    let mut out = format!("tezo {cmd} — {about}\n\nflags:\n");
    for s in specs {
        let kind = if s.is_switch {
            "".to_string()
        } else if let Some(d) = s.default {
            format!(" <value> (default: {d})")
        } else {
            " <value> (required)".to_string()
        };
        out.push_str(&format!("  --{}{}\n      {}\n", s.name, kind, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_defaults_and_overrides() {
        let specs = [ArgSpec::opt("steps", "100", "n steps"),
                     ArgSpec::switch("verbose", "chatty"),
                     ArgSpec::req("config", "model config")];
        let args = parse(&sv(&["--config", "tiny", "--steps=250", "--verbose"]), &specs).unwrap();
        assert_eq!(args.get_usize("steps").unwrap(), 250);
        assert_eq!(args.get_str("config").unwrap(), "tiny");
        assert!(args.has("verbose"));
    }

    #[test]
    fn missing_required_fails() {
        let specs = [ArgSpec::req("config", "model config")];
        assert!(parse(&sv(&[]), &specs).is_err());
    }

    #[test]
    fn unknown_flag_fails() {
        let specs = [ArgSpec::opt("a", "1", "")];
        assert!(parse(&sv(&["--nope", "2"]), &specs).is_err());
    }

    #[test]
    fn list_values() {
        let specs = [ArgSpec::opt("methods", "mezo,tezo", "")];
        let args = parse(&sv(&[]), &specs).unwrap();
        assert_eq!(args.get_list("methods").unwrap(), vec!["mezo", "tezo"]);
    }
}
