//! `tezo` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   train             fine-tune one task with one method
//!   train-dp          seed-synchronized data-parallel fine-tuning (fleet)
//!   sweep             run the Table 3/4/5 method x task grids (or --list for Table 6)
//!   checkpoint-verify verify every checkpoint descriptor + bin in a directory
//!   memory-report     render Table 7 / Table 9 / Fig 1(c) from the memory model
//!   rank-probe        recompute the Eq.(7) rank schedule and check the manifest
//!   inspect           artifact inventory + compile times for a config
//!   trace-report      summarize a `--telemetry-dir` trace (phases, stragglers)

use std::path::PathBuf;

use anyhow::{bail, Result};

use tezo::clix::{self, ArgSpec};
use tezo::config::{search_space, FleetConfig, FormPolicy, Method,
                   StragglerPolicy, TrainConfig, FORWARD_FORM_ARG_DEFAULT};
use tezo::coordinator::{autotune, rank, GuardPolicy};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::fleet::{task_job_factory, FleetTrainer, JobSpec, Transport};
use tezo::coordinator::metrics::{Phase, PhaseTimers};
use tezo::memmodel::{comm, tables};
use tezo::runtime::{ParamStore, Runtime};
use tezo::telemetry::{self, Telemetry};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "train" => cmd_train(rest),
        "train-dp" => cmd_train_dp(rest),
        "sweep" => cmd_sweep(rest),
        "checkpoint-verify" => cmd_checkpoint_verify(rest),
        "memory-report" => cmd_memory(rest),
        "rank-probe" => cmd_rank_probe(rest),
        "probe-variance" => cmd_probe_variance(rest),
        "generate" => cmd_generate(rest),
        "inspect" => cmd_inspect(rest),
        "trace-report" => cmd_trace_report(rest),
        "--version" | "version" => {
            println!("tezo {}", tezo::VERSION);
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `tezo help`"),
    }
}

fn print_help() {
    println!(
        "tezo {} — TeZO reproduction (Rust + JAX + Pallas)\n\n\
         commands:\n\
         \x20 train          fine-tune one synthetic task with one method\n\
         \x20 train-dp       seed-synchronized data-parallel training (--workers N)\n\
         \x20 sweep          Table 3/4/5 grids; --list prints Table 6\n\
         \x20 checkpoint-verify  verify checkpoint digests + lengths in a dir\n\
         \x20 memory-report  Table 7 / Table 9 / Fig 1(c) (analytic model)\n\
         \x20 rank-probe     recompute Eq.(7) ranks, verify vs manifest\n\
         \x20 probe-variance kappa-distribution diagnostics per ZO method\n\
         \x20 generate       greedy decoding through the eval artifact\n\
         \x20 inspect        artifact inventory for a config\n\
         \x20 trace-report   summarize a --telemetry-dir trace\n\
         \x20 help           this message\n\n\
         run `tezo <command> --help` for flags",
        tezo::VERSION
    );
}

// ---------------------------------------------------------------------------
// train
// ---------------------------------------------------------------------------

const TRAIN_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config (artifacts/<config>)"),
    ArgSpec::opt("method", "tezo", "optimizer: mezo|mezo-m|mezo-adam|lozo|lozo-m|subzo|zo-adamu|tezo|tezo-m|tezo-adam|fo-adam"),
    ArgSpec::opt("task", "sst2", "synthetic task name (see data::tasks)"),
    ArgSpec::opt("steps", "200", "training steps"),
    ArgSpec::opt("k", "16", "few-shot examples per class"),
    ArgSpec::opt("lr", "", "learning rate (default: Table-6 preset)"),
    ArgSpec::opt("rho", "1e-3", "perturbation rate"),
    ArgSpec::opt("seed", "0", "master seed"),
    ArgSpec::opt("eval-every", "0", "eval interval (0 = end only)"),
    ArgSpec::opt("eval-n", "128", "held-out eval examples"),
    ArgSpec::opt("loss-csv", "", "write the loss curve CSV here"),
    ArgSpec::opt("lr-schedule", "constant", "constant|linear|cosine"),
    ArgSpec::opt("kappa-clip", "0", "clip |kappa| at this value (0 = off)"),
    ArgSpec::opt("n-perturb", "1", "q-SPSA perturbations per step (SGD-form only)"),
    ArgSpec::opt("forward-form", FORWARD_FORM_ARG_DEFAULT,
                 "two-point loss form: auto (tuned per shape) | implicit | materialize"),
    ArgSpec::opt("save-to", "", "write a parameter checkpoint here at the end"),
    ArgSpec::opt("init-from", "", "initialize parameters from this checkpoint"),
    ArgSpec::opt("checkpoint-dir", "", "durable checkpoint + journal directory"),
    ArgSpec::opt("checkpoint-every", "0", "save a verified checkpoint every N steps (0 = off)"),
    ArgSpec::opt("checkpoint-keep", "2", "retained checkpoints (keep-last-K)"),
    ArgSpec::switch("resume", "resume from --checkpoint-dir: newest verified checkpoint + journal replay"),
    ArgSpec::opt("guard-nonfinite", "0", "guard: roll back after N consecutive non-finite losses (0 = off)"),
    ArgSpec::opt("guard-spike", "0", "guard: roll back when loss > factor x EWMA trend (0 = off)"),
    ArgSpec::opt("guard-ewma-alpha", "0.1", "guard: EWMA smoothing in (0, 1]"),
    ArgSpec::opt("guard-warmup", "8", "guard: finite losses before spike detection arms"),
    ArgSpec::opt("guard-max-rollbacks", "3", "guard: rollback budget before aborting"),
    ArgSpec::opt("guard-skip-steps", "0", "guard: updates suppressed (journaled as skips) after a rollback"),
    ArgSpec::opt("telemetry-dir", "", "write trace.jsonl + metrics.prom here"),
    ArgSpec::switch("quiet", "suppress per-step output"),
    ArgSpec::switch("help", "show help"),
];

/// Parse the training flags shared by `train` and `train-dp` (both specs
/// declare the same set — one parser keeps their semantics from drifting,
/// which the `train-dp --workers 1` parity guarantee depends on).
fn parse_train_cfg(args: &clix::Args) -> Result<TrainConfig> {
    let config = args.get_str("config")?;
    let method = Method::parse(args.get_str("method")?)?;
    let mut cfg = TrainConfig::with_preset(method, config);
    cfg.steps = args.get_usize("steps")?;
    cfg.rho = args.get_f32("rho")?;
    cfg.seed = args.get_u64("seed")?;
    cfg.eval_every = args.get_usize("eval-every")?;
    if let Some(lr) = args.get("lr") {
        if !lr.is_empty() {
            cfg.lr = lr.parse()?;
        }
    }
    cfg.lr_schedule = tezo::config::LrSchedule::parse(args.get_str("lr-schedule")?)?;
    cfg.kappa_clip = args.get_f32("kappa-clip")?;
    cfg.n_perturb = args.get_usize("n-perturb")?;
    cfg.forward_form = FormPolicy::parse(args.get_str("forward-form")?)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Parse the `--guard-*` flags shared by `train` and `train-dp` into a
/// [`GuardPolicy`] (the all-zero default leaves the guard disabled).
fn parse_guard(args: &clix::Args) -> Result<GuardPolicy> {
    let guard = GuardPolicy {
        nonfinite_streak: args.get_usize("guard-nonfinite")?,
        spike_factor: args.get_str("guard-spike")?.parse::<f64>()?,
        ewma_alpha: args.get_str("guard-ewma-alpha")?.parse::<f64>()?,
        warmup: args.get_usize("guard-warmup")?,
        max_rollbacks: args.get_usize("guard-max-rollbacks")?,
        skip_steps: args.get_usize("guard-skip-steps")?,
    };
    guard.validate()?;
    Ok(guard)
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, TRAIN_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("train", "fine-tune one task", TRAIN_SPECS));
        return Ok(());
    }
    let config = args.get_str("config")?;
    let method = Method::parse(args.get_str("method")?)?;
    let mut cfg = parse_train_cfg(&args)?;

    let rt = Runtime::open_config(config)?;
    let (telemetry_dir, tel) = telemetry_from_args(&args)?;
    // resolve the form policy exactly once, before any engine exists:
    // an explicit pin costs nothing, a warm tuning.json is a cache hit,
    // and only a genuine miss measures (compiling both forms as it goes)
    let resolution = autotune::resolve(&rt, &cfg, &tel)?;
    cfg.forward_form = FormPolicy::Pinned(resolution.form);
    println!("forward form: {} ({})", resolution.form.name(),
             resolution.source.name());
    // precompile exactly this method's pinned artifact set (+ the eval
    // head) so step 0 is pure execution; on the cached/pinned paths the
    // losing form's loss artifact is never compiled
    {
        let t0 = telemetry::Stopwatch::start();
        rt.warmup_method(cfg.method, resolution.form)?;
        if args.get_usize("eval-n")? > 0 {
            rt.warmup(&["eval_logits"])?;
        }
        println!("precompiled {} artifacts in {:.1}s",
                 rt.compiled_count(), t0.elapsed().as_secs_f64());
    }
    let mut params = match args.get("init-from") {
        Some(dir) if !dir.is_empty() => {
            let (p, step) = tezo::runtime::checkpoint::load(
                std::path::Path::new(dir), &rt.client, &rt.manifest)?;
            println!("initialized from checkpoint @ step {step} ({dir})");
            p
        }
        _ => ParamStore::load(&rt.client, &rt.manifest)?,
    };

    let task_name = args.get_str("task")?;
    let spec = tasks::spec_by_name(task_name)
        .ok_or_else(|| anyhow::anyhow!("unknown task {task_name:?}"))?;
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(spec, tok, rt.manifest.config.seq_len, cfg.seed);
    let label_tokens = task.label_tokens();
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, args.get_usize("k")?);
    let eval_batches = builder.eval_batches(args.get_usize("eval-n")?);

    let quiet = args.has("quiet");
    let mut trainer = Trainer::new(&rt, cfg.clone(), DataSource::Task(builder))
        .with_eval(eval_batches, label_tokens)
        .with_telemetry(tel.clone())
        .with_tuning(resolution.summary_json())
        .with_resume(args.has("resume"))
        .with_guard(parse_guard(&args)?);
    if let Some(dir) = args.get("checkpoint-dir") {
        if !dir.is_empty() {
            trainer = trainer.with_checkpointing(
                PathBuf::from(dir),
                args.get_u64("checkpoint-every")?,
                args.get_usize("checkpoint-keep")?);
        }
    }
    if !quiet {
        trainer.on_step = Some(Box::new(|step, loss| {
            if step % 20 == 0 {
                println!("step {step:5}  loss {loss:.4}");
            }
        }));
    }
    let outcome = trainer.run(&mut params)?;

    println!("\n== {} on {} ({} steps) ==", method.name(), args.get_str("task")?, cfg.steps);
    if let Some(step) = outcome.metrics.resumed_from {
        println!("resumed from checkpoint @ step {step} (journal replay)");
    }
    if outcome.metrics.rollbacks > 0 {
        println!("divergence guard: {} rollback(s)", outcome.metrics.rollbacks);
    }
    println!("loss: {:.4} -> {:.4}",
             outcome.metrics.initial_loss_avg(20), outcome.metrics.final_loss_avg(20));
    if let Some((step, acc)) = outcome.metrics.evals.last() {
        println!("accuracy @ step {step}: {:.1}%", acc * 100.0);
    }
    println!("wall: {:.1}s ({:.1} ms/step)", outcome.metrics.wall_seconds,
             outcome.metrics.seconds_per_step() * 1e3);
    for (name, secs, frac) in outcome.metrics.timers.breakdown() {
        println!("  {name:9} {secs:8.2}s  {:5.1}%", frac * 100.0);
    }
    println!("sampled elements: matrix {} vector {}",
             outcome.counter.matrix_elements, outcome.counter.vector_elements);
    println!("host->device staging: {} bytes uploaded, {} reused from pool \
              ({} resident)",
             outcome.staging.upload_bytes, outcome.staging.reused_bytes,
             outcome.staging.resident_bytes);
    println!("optimizer state: {} bytes", outcome.state_bytes);
    if outcome.skipped > 0 {
        println!("warning: {} non-finite steps skipped", outcome.skipped);
    }
    if let Some(path) = args.get("loss-csv") {
        if !path.is_empty() {
            outcome.metrics.write_loss_csv(&PathBuf::from(path))?;
            println!("loss curve -> {path}");
        }
    }
    if let Some(dir) = args.get("save-to") {
        if !dir.is_empty() {
            tezo::runtime::checkpoint::save(std::path::Path::new(dir),
                                            &rt.manifest, &params,
                                            cfg.steps as u64)?;
            println!("checkpoint -> {dir}");
        }
    }
    if let Some(dir) = &telemetry_dir {
        write_run_telemetry(dir, &tel, "tezo train",
                            &outcome.metrics.timers, None)?;
    }
    Ok(())
}

/// Parse `--telemetry-dir`: an enabled tracer plus the export target, or
/// the no-op tracer when the flag is absent.
fn telemetry_from_args(args: &clix::Args)
                       -> Result<(Option<PathBuf>, Telemetry)> {
    Ok(match args.get("telemetry-dir") {
        Some(d) if !d.is_empty() => {
            (Some(PathBuf::from(d)),
             Telemetry::new(telemetry::DEFAULT_RING_CAPACITY))
        }
        _ => (None, Telemetry::off()),
    })
}

/// Export one run's telemetry artifacts into `dir`: the Perfetto-loadable
/// Chrome trace, a Prometheus-style snapshot of the latency histograms,
/// and (fleet runs) the fleet summary JSON.
fn write_run_telemetry(dir: &std::path::Path, tel: &Telemetry, process: &str,
                       timers: &PhaseTimers,
                       fleet: Option<&tezo::fleet::FleetMetrics>) -> Result<()> {
    telemetry::export::write_trace_file(&dir.join("trace.jsonl"), tel, process)?;
    let mut prom = telemetry::export::PromWriter::new();
    for phase in Phase::ALL {
        let h = timers.hist(phase);
        if !h.is_empty() {
            prom.hist("tezo_phase_latency_ns", &[("phase", phase.name())], h);
        }
    }
    if let Some(fm) = fleet {
        prom.gauge("tezo_fleet_straggler_factor", &[], fm.straggler_factor());
        prom.gauge("tezo_fleet_straggler_wait_secs", &[],
                   fm.straggler_wait_secs());
        for (w, h) in fm.forward_hist.iter().enumerate() {
            if !h.is_empty() {
                let lane = w.to_string();
                prom.hist("tezo_round_forward_ns",
                          &[("worker", lane.as_str())], h);
            }
        }
        for (w, h) in fm.update_hist.iter().enumerate() {
            if !h.is_empty() {
                let lane = w.to_string();
                prom.hist("tezo_round_update_ns",
                          &[("worker", lane.as_str())], h);
            }
        }
        let summary = tezo::jsonx::to_string_pretty(&fm.summary_json());
        telemetry::export::write_text(&dir.join("fleet_summary.json"),
                                      &summary)?;
    }
    prom.counter_total("tezo_trace_dropped_events", &[], tel.dropped());
    telemetry::export::write_text(&dir.join("metrics.prom"), &prom.finish())?;
    println!("telemetry -> {}", dir.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// train-dp
// ---------------------------------------------------------------------------

const TRAIN_DP_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config (artifacts/<config>)"),
    ArgSpec::opt("method", "tezo", "ZO optimizer: mezo|mezo-m|mezo-adam|lozo|lozo-m|subzo|zo-adamu|tezo|tezo-m|tezo-adam"),
    ArgSpec::opt("workers", "2", "data-parallel worker replicas"),
    ArgSpec::opt("task", "sst2", "synthetic task name (see data::tasks)"),
    ArgSpec::opt("steps", "200", "training steps"),
    ArgSpec::opt("k", "16", "few-shot examples per class"),
    ArgSpec::opt("lr", "", "learning rate (default: Table-6 preset)"),
    ArgSpec::opt("rho", "1e-3", "perturbation rate"),
    ArgSpec::opt("seed", "0", "master seed"),
    ArgSpec::opt("eval-every", "0", "eval interval (0 = end only)"),
    ArgSpec::opt("eval-n", "128", "held-out eval examples"),
    ArgSpec::opt("loss-csv", "", "write the global loss curve CSV here"),
    ArgSpec::opt("lr-schedule", "constant", "constant|linear|cosine"),
    ArgSpec::opt("kappa-clip", "0", "clip |kappa| at this value (0 = off)"),
    ArgSpec::opt("n-perturb", "1", "q-SPSA perturbations per step (SGD-form only)"),
    ArgSpec::opt("forward-form", FORWARD_FORM_ARG_DEFAULT,
                 "two-point loss form: auto (tuned per shape) | implicit | materialize"),
    ArgSpec::opt("save-to", "", "worker 0 writes a checkpoint here at the end"),
    ArgSpec::opt("transport", "loopback", "fleet wire: loopback|tcp"),
    ArgSpec::opt("listen", "127.0.0.1:7700", "coordinator bind address (--transport tcp)"),
    ArgSpec::opt("connect", "", "worker mode: dial this coordinator and serve tickets"),
    ArgSpec::opt("straggler", "wait", "round-deadline policy: wait|drop"),
    ArgSpec::opt("straggler-timeout-ms", "30000", "drop policy: round deadline in ms"),
    ArgSpec::opt("checkpoint-every", "0", "publish a catch-up checkpoint every N steps (0 = off)"),
    ArgSpec::opt("checkpoint-dir", "", "where step checkpoints are published/loaded (also the coordinator journal)"),
    ArgSpec::switch("resume", "restart from the coordinator journal in --checkpoint-dir"),
    ArgSpec::opt("guard-nonfinite", "0", "guard: roll back after N consecutive non-finite losses (0 = off)"),
    ArgSpec::opt("guard-spike", "0", "guard: roll back when loss > factor x EWMA trend (0 = off)"),
    ArgSpec::opt("guard-ewma-alpha", "0.1", "guard: EWMA smoothing in (0, 1]"),
    ArgSpec::opt("guard-warmup", "8", "guard: finite losses before spike detection arms"),
    ArgSpec::opt("guard-max-rollbacks", "3", "guard: rollback budget before aborting"),
    ArgSpec::opt("guard-skip-steps", "0", "guard: updates suppressed (journaled as skips) after a rollback"),
    ArgSpec::opt("max-restarts", "0", "worker deaths tolerated before aborting (0 = fail fast)"),
    ArgSpec::opt("reconnect-attempts", "10", "worker mode: dial attempts per reconnect"),
    ArgSpec::opt("reconnect-backoff-ms", "100", "worker mode: base backoff between attempts"),
    ArgSpec::opt("telemetry-dir", "", "write trace.jsonl + metrics.prom + fleet_summary.json here"),
    ArgSpec::switch("quiet", "suppress per-step output"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_train_dp(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, TRAIN_DP_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("train-dp",
                                       "seed-synchronized data-parallel training",
                                       TRAIN_DP_SPECS));
        return Ok(());
    }
    let config = args.get_str("config")?;
    let save_to = match args.get("save-to") {
        Some(d) if !d.is_empty() => Some(PathBuf::from(d)),
        _ => None,
    };
    let checkpoint_dir = match args.get("checkpoint-dir") {
        Some(d) if !d.is_empty() => Some(PathBuf::from(d)),
        _ => None,
    };

    // worker mode: everything else (method, steps, task) comes from the
    // coordinator's handshake, so conflicting local flags cannot desync it
    if let Some(addr) = args.get("connect") {
        if !addr.is_empty() {
            let rc = tezo::fleet::tcp::Reconnect {
                attempts: args.get_usize("reconnect-attempts")? as u32,
                base_delay: std::time::Duration::from_millis(
                    args.get_u64("reconnect-backoff-ms")?),
                ..Default::default()
            };
            let dir = tezo::artifacts_root().join(config);
            println!("worker: dialing {addr} (artifacts: {config})");
            tezo::fleet::worker::run_tcp_worker(addr, &dir, save_to,
                                                checkpoint_dir, rc)?;
            println!("worker: fleet stopped cleanly");
            return Ok(());
        }
    }

    let method = Method::parse(args.get_str("method")?)?;
    let cfg = parse_train_cfg(&args)?;
    let mut fleet = FleetConfig::new(args.get_usize("workers")?);
    fleet.straggler = match args.get_str("straggler")? {
        "wait" => StragglerPolicy::Wait,
        "drop" => StragglerPolicy::DropSkip {
            timeout_ms: args.get_u64("straggler-timeout-ms")?,
        },
        other => bail!("unknown straggler policy {other:?} (wait|drop)"),
    };
    fleet.checkpoint_every = args.get_usize("checkpoint-every")?;
    fleet.max_restarts = args.get_usize("max-restarts")?;
    fleet.validate(&cfg)?;

    let task_name = args.get_str("task")?.to_string();
    let k_shot = args.get_usize("k")?;
    let eval_n = args.get_usize("eval-n")?;
    let factory = task_job_factory(task_name.clone(), cfg.seed, k_shot,
                                   eval_n, save_to);

    let transport = match args.get_str("transport")? {
        "loopback" => Transport::Loopback,
        "tcp" => {
            let listen = args.get_str("listen")?.to_string();
            println!("coordinator: listening on {listen} for {} workers",
                     fleet.workers);
            Transport::TcpListen(listen)
        }
        other => bail!("unknown transport {other:?} (loopback|tcp)"),
    };

    let dir = tezo::artifacts_root().join(config);
    let n_params = tezo::runtime::Manifest::load(&dir)?.config.n_params as u64;
    let (telemetry_dir, tel) = telemetry_from_args(&args)?;
    let mut trainer = FleetTrainer::new(fleet, cfg.clone(), dir, factory)
        .with_transport(transport)
        .with_job_spec(JobSpec {
            task: task_name,
            k_shot: k_shot as u32,
            eval_n: eval_n as u32,
        })
        .with_telemetry(tel.clone())
        .with_resume(args.has("resume"))
        .with_guard(parse_guard(&args)?);
    if let Some(d) = checkpoint_dir {
        trainer = trainer.with_checkpoint_dir(d);
    }
    if !args.has("quiet") {
        trainer.on_step = Some(Box::new(|step, loss| {
            if step % 20 == 0 {
                println!("step {step:5}  loss {loss:.4}");
            }
        }));
    }
    let outcome = trainer.run()?;

    println!("\n== {} on {} x{} workers ({} steps) ==",
             method.name(), args.get_str("task")?, fleet.workers, cfg.steps);
    if let Some(step) = outcome.metrics.resumed_from {
        println!("resumed from checkpoint @ step {step} (journal replay)");
    }
    if outcome.metrics.rollbacks > 0 {
        println!("divergence guard: {} rollback(s)", outcome.metrics.rollbacks);
    }
    println!("loss: {:.4} -> {:.4}",
             outcome.metrics.initial_loss_avg(20),
             outcome.metrics.final_loss_avg(20));
    if let Some((step, acc)) = outcome.metrics.evals.last() {
        println!("accuracy @ step {step}: {:.1}%", acc * 100.0);
    }
    println!("wall: {:.1}s ({:.1} ms/step)", outcome.metrics.wall_seconds,
             outcome.metrics.seconds_per_step() * 1e3);
    println!("per-worker phases (forward / update seconds):");
    for (w, fwd, upd) in outcome.fleet.per_worker() {
        println!("  worker {w}: {fwd:8.2}s / {upd:8.2}s");
    }
    println!("straggler factor: {:.3}  (fast replicas idled {:.2}s)",
             outcome.fleet.straggler_factor(),
             outcome.fleet.straggler_wait_secs());
    let scalar = outcome.fleet.comm.total_bytes();
    let allreduce = comm::gradient_allreduce_step_bytes(n_params, fleet.workers as u64)
        * cfg.steps as u64;
    println!("communication: {scalar} bytes total ({} tickets, {} results)",
             outcome.fleet.comm.tickets, outcome.fleet.comm.results);
    let wire = outcome.fleet.comm.total_wire_bytes();
    if wire > 0 {
        println!("  on the wire (framed): {wire} bytes in {} frames \
                  ({} down / {} up)",
                 outcome.fleet.comm.frames_down + outcome.fleet.comm.frames_up,
                 outcome.fleet.comm.wire_down, outcome.fleet.comm.wire_up);
    }
    if fleet.workers > 1 {
        println!("  gradient all-reduce would move {allreduce} bytes \
                  ({:.1e}x more)", allreduce as f64 / scalar.max(1) as f64);
    }
    let fm = &outcome.fleet;
    if fm.rejoins + fm.drops + fm.checkpoints + fm.stale_events > 0 {
        println!("fault tolerance: {} rejoins, {} straggler drops, {} \
                  degraded rounds, {} checkpoints, {} stale events",
                 fm.rejoins, fm.drops, fm.degraded_rounds, fm.checkpoints,
                 fm.stale_events);
    }
    println!("optimizer state per replica: {} bytes", outcome.state_bytes);
    if outcome.skipped > 0 {
        println!("warning: {} non-finite steps skipped (in lockstep)",
                 outcome.skipped);
    }
    if let Some(path) = args.get("loss-csv") {
        if !path.is_empty() {
            outcome.metrics.write_loss_csv(&PathBuf::from(path))?;
            println!("loss curve -> {path}");
        }
    }
    if let Some(d) = &telemetry_dir {
        write_run_telemetry(d, &tel, "tezo train-dp",
                            &outcome.metrics.timers, Some(&outcome.fleet))?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// checkpoint-verify
// ---------------------------------------------------------------------------

const CKPT_VERIFY_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("dir", "", "checkpoint directory to verify"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_checkpoint_verify(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, CKPT_VERIFY_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help(
            "checkpoint-verify",
            "verify every checkpoint descriptor + bin in a directory",
            CKPT_VERIFY_SPECS));
        return Ok(());
    }
    let dir = args.get_str("dir")?;
    if dir.is_empty() {
        bail!("checkpoint-verify needs --dir <checkpoint directory>");
    }
    let dir = std::path::Path::new(dir);
    let cands = tezo::runtime::checkpoint::candidates(dir);
    if cands.is_empty() {
        bail!("{}: no checkpoint descriptors found", dir.display());
    }
    println!("== checkpoint-verify: {} ({} descriptor(s)) ==",
             dir.display(), cands.len());
    let mut bad = 0usize;
    for name in &cands {
        match tezo::runtime::checkpoint::verify_doc(dir, name) {
            Ok(rep) => {
                println!("  {name}: ok  step {}  config {}  {} bins \
                          ({} digested)  {} bytes",
                         rep.step, rep.config, rep.n_bins, rep.digested,
                         rep.total_bytes);
            }
            Err(e) => {
                bad += 1;
                println!("  {name}: CORRUPT — {e:#}");
            }
        }
    }
    if bad > 0 {
        bail!("{bad} of {} descriptor(s) failed verification", cands.len());
    }
    println!("all descriptors verified");
    Ok(())
}

// ---------------------------------------------------------------------------
// sweep
// ---------------------------------------------------------------------------

const SWEEP_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config"),
    ArgSpec::opt("table", "4", "paper table to regenerate: 3|4|5"),
    ArgSpec::opt("steps", "300", "steps per cell"),
    ArgSpec::opt("k", "16", "examples per class"),
    ArgSpec::opt("methods", "", "override method list (comma-separated)"),
    ArgSpec::opt("csv", "", "write the result grid CSV here"),
    ArgSpec::switch("list", "print the Table-6 search space and exit"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, SWEEP_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("sweep", "table grids", SWEEP_SPECS));
        return Ok(());
    }
    if args.has("list") {
        println!("== Table 6 — hyperparameter search space ==");
        for m in Method::ALL {
            println!("\n[{}]", m.name());
            for (k, vs) in search_space(m) {
                println!("  {k}: {}", vs.join(", "));
            }
        }
        return Ok(());
    }
    let table: u8 = args.get_str("table")?.parse()?;
    let methods: Vec<Method> = match args.get("methods") {
        Some(ms) if !ms.is_empty() => {
            ms.split(',').map(Method::parse).collect::<Result<_>>()?
        }
        _ => default_methods(table),
    };
    let task_names: Vec<&str> = table_tasks(table);
    println!("sweep table {table}: {} methods x {} tasks", methods.len(), task_names.len());

    let config = args.get_str("config")?;
    let rt = Runtime::open_config(config)?;
    let steps = args.get_usize("steps")?;
    let k = args.get_usize("k")?;
    let mut rows = Vec::new();
    for m in &methods {
        let mut cells = Vec::new();
        for tname in &task_names {
            let acc = run_cell(&rt, config, *m, tname, steps, k)?;
            cells.push(format!("{:.1}", acc * 100.0));
            println!("  {} / {tname}: {:.1}%", m.name(), acc * 100.0);
        }
        rows.push((m.name().to_string(), cells));
    }
    println!("\n== Table {table} analogue (accuracy %) ==");
    print!("{:12}", "");
    for t in &task_names {
        print!("{t:>9}");
    }
    println!();
    let mut csv = String::from("method");
    for t in &task_names {
        csv.push(',');
        csv.push_str(t);
    }
    csv.push('\n');
    for (name, cells) in &rows {
        print!("{name:12}");
        csv.push_str(name);
        for c in cells {
            print!("{c:>9}");
            csv.push(',');
            csv.push_str(c);
        }
        println!();
        csv.push('\n');
    }
    if let Some(path) = args.get("csv") {
        if !path.is_empty() {
            let p = PathBuf::from(path);
            if let Some(dir) = p.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(&p, csv)?;
            println!("grid -> {path}");
        }
    }
    Ok(())
}

/// Method rows of each paper table.
pub fn default_methods(table: u8) -> Vec<Method> {
    match table {
        3 => vec![Method::FoAdam, Method::Mezo, Method::Subzo, Method::Lozo,
                  Method::Tezo, Method::MezoM, Method::LozoM, Method::TezoM],
        5 => vec![Method::Mezo, Method::Lozo, Method::Subzo, Method::Tezo,
                  Method::MezoAdam, Method::TezoAdam],
        _ => vec![Method::Mezo, Method::Subzo, Method::Lozo, Method::Tezo,
                  Method::MezoM, Method::LozoM, Method::TezoM,
                  Method::MezoAdam, Method::ZoAdamu, Method::TezoAdam],
    }
}

/// Task columns of each paper table.
pub fn table_tasks(table: u8) -> Vec<&'static str> {
    match table {
        3 => vec!["sst5", "snli", "mnli", "qnli", "trec"],
        5 => vec!["sst2", "rte", "wsc", "wic"],
        _ => tasks::ALL_TASKS.iter().filter(|t| t.table == 4).map(|t| t.name).collect(),
    }
}

fn run_cell(rt: &Runtime, config: &str, method: Method, tname: &str,
            steps: usize, k: usize) -> Result<f64> {
    let mut cfg = TrainConfig::with_preset(method, config);
    cfg.steps = steps;
    let mut params = ParamStore::load(&rt.client, &rt.manifest)?;
    let spec = tasks::spec_by_name(tname)
        .ok_or_else(|| anyhow::anyhow!("unknown task {tname:?}"))?;
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(spec, tok, rt.manifest.config.seq_len, cfg.seed);
    let label_tokens = task.label_tokens();
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, k);
    let eval_batches = builder.eval_batches(128);
    let mut trainer = Trainer::new(rt, cfg, DataSource::Task(builder))
        .with_eval(eval_batches, label_tokens);
    let outcome = trainer.run(&mut params)?;
    Ok(outcome.metrics.evals.last().map(|e| e.1).unwrap_or(0.0))
}

// ---------------------------------------------------------------------------
// memory-report / rank-probe / inspect
// ---------------------------------------------------------------------------

const MEM_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("table", "7", "which artifact: 7|9|fig1c|forms|all"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_memory(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, MEM_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("memory-report", "memory tables", MEM_SPECS));
        return Ok(());
    }
    match args.get_str("table")? {
        "7" => tables::table7().print(),
        "9" => tables::table9().print(),
        "fig1c" => tables::fig1c().print(),
        "forms" => tables::forward_forms().print(),
        "all" => {
            tables::table7().print();
            tables::table9().print();
            tables::fig1c().print();
            tables::forward_forms().print();
        }
        other => bail!("unknown table {other:?}"),
    }
    Ok(())
}

const RANK_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_rank_probe(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, RANK_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("rank-probe", "Eq.(7) ranks", RANK_SPECS));
        return Ok(());
    }
    let rt = Runtime::open_config(args.get_str("config")?)?;
    let params = ParamStore::load(&rt.client, &rt.manifest)?;
    let schedule = rank::rank_schedule(&rt.manifest, &params)?;
    println!("== Eq.(7) rank schedule ({}) ==", rt.manifest.config.name);
    for mr in &rt.manifest.matrix_ranks {
        let ours = schedule.get(&mr.name).copied().unwrap_or(0);
        let mark = if ours == mr.rank { "ok" } else { "MISMATCH" };
        println!("  {:24} {:5}x{:<5}  manifest r={:3}  rust r={:3}  {}",
                 mr.name, mr.m, mr.n, mr.rank, ours, mark);
    }
    let mismatches = rank::verify_against_manifest(&rt.manifest, &params)?;
    if mismatches.is_empty() {
        println!("rank schedule verified: python == rust");
    } else {
        println!("{} mismatches (SVD threshold sensitivity)", mismatches.len());
    }
    Ok(())
}

const PROBE_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config"),
    ArgSpec::opt("methods", "mezo,lozo,subzo,tezo", "ZO methods to probe"),
    ArgSpec::opt("task", "sst2", "task supplying the probe batch"),
    ArgSpec::opt("samples", "32", "independent perturbation seeds"),
    ArgSpec::opt("rho", "1e-3", "perturbation rate"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_probe_variance(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, PROBE_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("probe-variance", "kappa diagnostics", PROBE_SPECS));
        return Ok(());
    }
    let rt = Runtime::open_config(args.get_str("config")?)?;
    let mut params = ParamStore::load(&rt.client, &rt.manifest)?;
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let tname = args.get_str("task")?;
    let spec = tasks::spec_by_name(tname)
        .ok_or_else(|| anyhow::anyhow!("unknown task {tname:?}"))?;
    let task = Task::new(spec, tok, rt.manifest.config.seq_len, 0);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let batch = builder.train_batch(0, 0);
    let k = args.get_usize("samples")?;
    let rho = args.get_f32("rho")?;
    println!("== kappa distribution over {k} seeds (rho={rho}) ==");
    println!("{:10} {:>12} {:>12} {:>12} {:>8}", "method", "mean", "std",
             "E[k^2]", "sign%");
    for mname in args.get_list("methods")? {
        let method = Method::parse(&mname)?;
        let s = tezo::coordinator::probe::kappa_distribution(
            &rt, &mut params, &batch, method, rho, k, 7)?;
        println!("{:10} {:>12.4} {:>12.4} {:>12.4} {:>7.0}%",
                 s.method.name(), s.mean, s.std, s.second_moment,
                 s.sign_consistency * 100.0);
    }
    println!("\n(E[kappa^2] tracks the estimator's variance constant; sign%\n\
              is the single-probe informativeness — see EXPERIMENTS.md E11)");
    Ok(())
}

const GEN_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config"),
    ArgSpec::opt("checkpoint", "", "load params from this checkpoint dir"),
    ArgSpec::opt("new-tokens", "16", "tokens to generate per row"),
    ArgSpec::opt("rows", "2", "corpus prompts to decode"),
    ArgSpec::opt("prompt-len", "16", "prompt length (corpus tokens)"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_generate(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, GEN_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("generate", "greedy decoding", GEN_SPECS));
        return Ok(());
    }
    let rt = Runtime::open_config(args.get_str("config")?)?;
    let params = match args.get("checkpoint") {
        Some(dir) if !dir.is_empty() => {
            let (p, step) = tezo::runtime::checkpoint::load(
                std::path::Path::new(dir), &rt.client, &rt.manifest)?;
            println!("loaded checkpoint @ step {step} from {dir}");
            p
        }
        _ => ParamStore::load(&rt.client, &rt.manifest)?,
    };
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let corpus = tezo::data::Corpus::new(tok, rt.manifest.config.seq_len, 1);
    let rows = args.get_usize("rows")?.min(rt.manifest.config.batch);
    let plen = args.get_usize("prompt-len")?;
    let prompts: Vec<Vec<i32>> = (0..rows)
        .map(|i| corpus.sequence(i as u64).0[..plen].to_vec())
        .collect();
    let out = tezo::coordinator::generate::greedy_generate(
        &rt, &params, &prompts, args.get_usize("new-tokens")?)?;
    for (i, row) in out.iter().enumerate() {
        let (p, gen) = row.split_at(plen);
        println!("row {i}: prompt {p:?}\n        -> {gen:?}");
    }
    Ok(())
}

const INSPECT_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("config", "tiny", "model config"),
    ArgSpec::opt("hlo", "", "print op histogram for this artifact"),
    ArgSpec::switch("compile", "compile every artifact and report times"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, INSPECT_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("inspect", "artifact inventory", INSPECT_SPECS));
        return Ok(());
    }
    let rt = Runtime::open_config(args.get_str("config")?)?;
    if let Some(art) = args.get("hlo") {
        if !art.is_empty() {
            let meta = rt.manifest.artifact(art)?;
            let stats = tezo::runtime::hlo_stats::HloStats::from_file(
                &rt.manifest.dir.join(&meta.file))?;
            println!("== HLO stats: {art} ==");
            println!("instructions: {}", stats.instructions);
            println!("largest tensor: {} ({} elements)",
                     stats.largest_shape, stats.largest_tensor);
            println!("peak temp bytes: {} (all values)", stats.peak_temp_bytes);
            println!("peak param-shaped temp bytes: {} (perturbed-weight \
                      copies; total {} per call)",
                     stats.peak_param_temp_bytes, stats.param_temp_total_bytes);
            if let Some(form) = &meta.forward_form {
                println!("forward form: {form}");
            }
            for (op, n) in stats.top_ops(20) {
                println!("  {op:32} {n}");
            }
            return Ok(());
        }
    }
    let c = &rt.manifest.config;
    println!("config {}: d={} L={} heads={} ff={} vocab={} seq={} batch={} params={}",
             c.name, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.vocab, c.seq_len,
             c.batch, c.n_params);
    println!("rank schedule: r_max={} threshold={}", c.r_max, c.rank_threshold);
    for mr in &rt.manifest.matrix_ranks {
        println!("  {:24} {:5}x{:<5} r={}", mr.name, mr.m, mr.n, mr.rank);
    }
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for (name, a) in &rt.manifest.artifacts {
        let sz = std::fs::metadata(rt.manifest.dir.join(&a.file))
            .map(|m| m.len())
            .unwrap_or(0);
        println!("  {name:24} {:3} in / {:3} out  {:8} bytes",
                 a.inputs.len(), a.outputs.len(), sz);
    }
    if args.has("compile") {
        let names: Vec<String> = rt.manifest.artifacts.keys().cloned().collect();
        for n in &names {
            let t = telemetry::Stopwatch::start();
            rt.executable(n)?;
            println!("  compiled {n} in {:.2}s", t.elapsed().as_secs_f64());
        }
        println!("total compile: {:.1}s for {} artifacts",
                 rt.compile_seconds(), rt.compiled_count());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// trace-report
// ---------------------------------------------------------------------------

const TRACE_REPORT_SPECS: &[ArgSpec] = &[
    ArgSpec::opt("trace", "out/trace/trace.jsonl",
                 "trace file written by --telemetry-dir"),
    ArgSpec::opt("slowest", "5", "how many slowest steps to list"),
    ArgSpec::switch("check", "validate the trace schema and exit"),
    ArgSpec::switch("help", "show help"),
];

fn cmd_trace_report(argv: &[String]) -> Result<()> {
    let args = clix::parse(argv, TRACE_REPORT_SPECS)?;
    if args.has("help") {
        print!("{}", clix::render_help("trace-report",
                                       "summarize a telemetry trace",
                                       TRACE_REPORT_SPECS));
        return Ok(());
    }
    telemetry::report::trace_report(args.get_str("trace")?,
                                    args.has("check"),
                                    args.get_usize("slowest")?)
}
