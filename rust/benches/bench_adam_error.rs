//! Fig 8 / Appendix A.2 reproduction: accumulated error of the lightweight
//! (separable) second moment, ||E_t|| = ||V_t - V̂_t||_F / (m n), over
//! training steps for several model widths.
//!
//! The paper's claim: the error decreases as the model grows, justifying
//! dropping the zero-mean cross term for LLM-sized layers. The paper plots
//! m = n in {1024, 2048, 4096}, r = 64 over 1000 steps; we sweep scaled
//! widths (the trend is the target) and write the full curves as CSV.
//!
//! Run: `cargo bench --bench bench_adam_error`.

use tezo::benchkit::Report;
use tezo::rngx::normal_rng;
use tezo::tensor::Matrix;

fn main() {
    let fast = std::env::var_os("TEZO_BENCH_FAST").is_some();
    let steps = if fast { 100 } else { 1000 };
    let sizes: &[usize] = if fast { &[64, 128, 256] } else { &[128, 256, 512, 1024] };
    let r = 32;
    let beta2 = 0.99f32;

    let mut rep = Report::new(
        &format!("Fig 8 — mean ||E_t||_F/(mn) over {steps} steps, r={r}"),
        &["mean ||E_t||", "final ||E_t||"],
    );
    let mut csv = String::from("step");
    for &s in sizes {
        csv.push_str(&format!(",m{s}"));
    }
    csv.push('\n');
    let mut curves: Vec<Vec<f64>> = Vec::new();

    for &size in sizes {
        let (m, n) = (size, size);
        let mut gen = normal_rng(size as u64);
        let u = Matrix::randn(m, r, &mut gen);
        let v = Matrix::randn(n, r, &mut gen);
        let u2 = Matrix::from_vec(m, r, u.data.iter().map(|x| x * x).collect()).unwrap();
        let v2 = Matrix::from_vec(n, r, v.data.iter().map(|x| x * x).collect()).unwrap();
        let mut vt = Matrix::zeros(m, n);
        let mut vhat = Matrix::zeros(m, n);
        let mut curve = Vec::with_capacity(steps);
        for _ in 0..steps {
            let tau: Vec<f32> = (0..r).map(|_| gen.next_f32()).collect();
            let z = Matrix::cpd_slice(&u, &v, &tau).unwrap();
            let z2 = Matrix::from_vec(m, n, z.data.iter().map(|x| x * x).collect()).unwrap();
            let tau2: Vec<f32> = tau.iter().map(|t| t * t).collect();
            let sep = Matrix::cpd_slice(&u2, &v2, &tau2).unwrap();
            vt.scale(beta2);
            vt.axpy(1.0 - beta2, &z2).unwrap();
            vhat.scale(beta2);
            vhat.axpy(1.0 - beta2, &sep).unwrap();
            let mut d = vt.clone();
            d.axpy(-1.0, &vhat).unwrap();
            curve.push(d.fro_norm() / (m as f64 * n as f64));
        }
        let mean: f64 = curve.iter().sum::<f64>() / curve.len() as f64;
        rep.add_row(&format!("m=n={size}"), vec![
            format!("{mean:.3e}"),
            format!("{:.3e}", curve.last().unwrap()),
        ]);
        curves.push(curve);
    }
    for t in 0..steps {
        csv.push_str(&format!("{t}"));
        for c in &curves {
            csv.push_str(&format!(",{:.6e}", c[t]));
        }
        csv.push('\n');
    }
    std::fs::create_dir_all("out").ok();
    std::fs::write("out/fig8_adam_error.csv", csv).ok();
    rep.print();
    println!("curves -> out/fig8_adam_error.csv");
    // the trend assertion (also a hard test in theory_validation.rs)
    let means: Vec<f64> = curves.iter()
        .map(|c| c.iter().sum::<f64>() / c.len() as f64)
        .collect();
    for w in means.windows(2) {
        assert!(w[1] < w[0], "||E_t|| must shrink with size: {means:?}");
    }
    println!("trend verified: error decreases with model size (paper Fig 8)");
}
