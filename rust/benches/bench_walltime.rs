//! Table 8 / Fig 3(b) reproduction: wall-clock per training iteration and
//! per-phase breakdown, per method, on the configs whose artifacts exist
//! (tiny always; small/medium when built).
//!
//! The paper's claim under test: TeZO ~ MeZO step time; TeZO-Adam clearly
//! faster than MeZO-Adam (1.5-1.6x on H100); low-rank overhead only pays
//! off as the model grows. Absolute numbers here are CPU-PJRT, the
//! *ratios* are the reproduction target.
//!
//! Run: `cargo bench --bench bench_walltime` (TEZO_BENCH_FAST=1 to shrink).

use std::time::Instant;

use tezo::benchkit::{fmt_time, write_json_value, Report};
use tezo::config::{FormPolicy, ForwardForm, Method, TrainConfig};
use tezo::coordinator::autotune;
use tezo::coordinator::metrics::Phase;
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::runtime::tune::{self, TuneSource};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::jsonx::Value;
use tezo::runtime::hlo_stats::HloStats;
use tezo::runtime::{ParamStore, Runtime};
use tezo::telemetry::{self, Telemetry};

const METHODS: [Method; 10] = [
    Method::Mezo, Method::Subzo, Method::Lozo, Method::Tezo,
    Method::MezoM, Method::LozoM, Method::TezoM,
    Method::MezoAdam, Method::ZoAdamu, Method::TezoAdam,
];

fn main() {
    let fast = std::env::var_os("TEZO_BENCH_FAST").is_some();
    let steps = if fast { 6 } else { 30 };
    // TEZO_BENCH_CONFIGS limits the sweep (the bigger configs cost minutes
    // of XLA compile + seconds per step on CPU)
    let configs = std::env::var("TEZO_BENCH_CONFIGS").unwrap_or_else(|_| {
        if fast { "tiny,tiny_jnp".into() } else { "tiny,tiny_jnp,small,medium".into() }
    });
    let mut form_entries: Vec<(String, Value)> = Vec::new();
    let mut auto_entries: Vec<(String, Value)> = Vec::new();
    let mut tel_entry: Option<Value> = None;
    for config in configs.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let dir = tezo::artifacts_root().join(config);
        if !dir.join("manifest.json").exists() {
            println!("(skipping {config}: artifacts missing)");
            continue;
        }
        bench_config(config, steps);
        if let Some(v) = bench_forward_forms(config, steps) {
            form_entries.push((config.to_string(), v));
        }
        if let Some(v) = bench_auto_tuning(config, steps) {
            auto_entries.push((config.to_string(), v));
        }
        if tel_entry.is_none() {
            tel_entry = bench_telemetry_overhead(config, steps);
        }
    }
    if let Some(entry) = tel_entry {
        let doc = Value::obj(vec![
            ("snapshot", Value::str("telemetry on/off step-time overhead")),
            ("run", entry),
        ]);
        let path = std::path::PathBuf::from("out/BENCH_PR8.json");
        match write_json_value(&path, &doc) {
            Ok(()) => println!("telemetry overhead snapshot -> {}", path.display()),
            Err(e) => println!("(snapshot write failed: {e})"),
        }
    }
    if !form_entries.is_empty() {
        // the perf-trajectory snapshot (committed as BENCH_PR5.json at the
        // repo root; python/bench_forward_forms.py emits the same shape
        // from the build-time side)
        let doc = Value::obj(vec![
            ("snapshot", Value::str("forward-form walltime + hlo temp stats")),
            ("configs", Value::obj(form_entries.iter()
                .map(|(k, v)| (k.as_str(), v.clone()))
                .collect())),
        ]);
        let path = std::path::PathBuf::from("out/BENCH_PR5.json");
        match write_json_value(&path, &doc) {
            Ok(()) => println!("forward-form snapshot -> {}", path.display()),
            Err(e) => println!("(snapshot write failed: {e})"),
        }
    }
    if !auto_entries.is_empty() {
        // the PR 9 snapshot (committed as BENCH_PR9.json at the repo root):
        // auto must match the best pinned form per shape, recovering the
        // small-config regression BENCH_PR5 recorded for always-implicit
        let doc = Value::obj(vec![
            ("snapshot",
             Value::str("auto vs pinned forward-form walltime + amortized \
                         tuning cost")),
            ("configs", Value::obj(auto_entries.iter()
                .map(|(k, v)| (k.as_str(), v.clone()))
                .collect())),
        ]);
        let path = std::path::PathBuf::from("out/BENCH_PR9.json");
        match write_json_value(&path, &doc) {
            Ok(()) => println!("autotune snapshot -> {}", path.display()),
            Err(e) => println!("(snapshot write failed: {e})"),
        }
    }
}

/// Implicit vs materialized forward: train `tezo` under both forms and
/// compare the forward-phase seconds; pair with the static per-artifact
/// temp metrics from `hlo_stats`. Returns the JSON entry for the snapshot,
/// or None when the config predates the implicit artifacts.
fn bench_forward_forms(config: &str, steps: usize) -> Option<Value> {
    let rt = Runtime::open(&tezo::artifacts_root().join(config)).expect("runtime");
    rt.manifest.artifact("tezo_loss_pm_implicit").ok()?;
    let mut rep = Report::new(
        &format!("Forward forms — tezo two-point loss ({config})"),
        &["fwd ms/step", "ms/step", "peak param temp B", "param temp B/call"],
    );
    let mut fields: Vec<(&str, Value)> = Vec::new();
    let mut fwd_ms = [0f64; 2];
    for (slot, form) in [ForwardForm::Materialize, ForwardForm::Implicit]
        .into_iter()
        .enumerate()
    {
        let mut cfg = TrainConfig::with_preset(Method::Tezo, config);
        cfg.steps = steps;
        cfg.forward_form = FormPolicy::Pinned(form);
        let mut params = ParamStore::load(&rt.client, &rt.manifest).expect("params");
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("rte").unwrap(), tok,
                             rt.manifest.config.seq_len, 0);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        rt.warmup_method(Method::Tezo, form).expect("warmup");
        let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder));
        let outcome = trainer.run(&mut params).expect("train");
        let fwd = outcome.metrics.timers.seconds(Phase::Forward)
            / steps as f64 * 1e3;
        fwd_ms[slot] = fwd;
        let ms = outcome.metrics.wall_seconds / steps as f64 * 1e3;
        let artifact = rt.manifest.loss_artifact(Method::Tezo, form);
        let meta = rt.manifest.artifact(artifact).expect("meta");
        let stats = HloStats::from_file(&rt.manifest.dir.join(&meta.file))
            .expect("hlo stats");
        rep.add_row(form.name(), vec![
            format!("{fwd:.1}"),
            format!("{ms:.1}"),
            format!("{}", stats.peak_param_temp_bytes),
            format!("{}", stats.param_temp_total_bytes),
        ]);
        fields.push((form.name(),
            Value::obj(vec![
                ("forward_ms_per_step", Value::f(fwd)),
                ("ms_per_step", Value::f(ms)),
                ("artifact", Value::str(artifact)),
                ("peak_temp_bytes", Value::i(stats.peak_temp_bytes as i64)),
                ("peak_param_temp_bytes",
                 Value::i(stats.peak_param_temp_bytes as i64)),
                ("param_temp_total_bytes",
                 Value::i(stats.param_temp_total_bytes as i64)),
            ])));
    }
    fields.push(("implicit_forward_speedup",
                 Value::f(fwd_ms[0] / fwd_ms[1].max(1e-9))));
    rep.print();
    Some(Value::obj(fields))
}

/// PR 9: `--forward-form auto` against both pinned forms on this config.
///
/// Deletes any persisted `tuning.json` first so the cold resolve really
/// measures, then resolves again to price the warm (cache-hit) path, then
/// trains once per arm: both pinned forms plus a run under the tuned
/// winner. The snapshot asserts what the autotuner promises — auto is
/// never slower than the better pinned form beyond noise, and the one-off
/// measurement cost amortizes to microseconds per step.
fn bench_auto_tuning(config: &str, steps: usize) -> Option<Value> {
    let rt = Runtime::open(&tezo::artifacts_root().join(config)).expect("runtime");
    rt.manifest.artifact("tezo_loss_pm_implicit").ok()?;
    std::fs::remove_file(tune::TuningTable::path(&rt.manifest.dir)).ok();
    let cfg = TrainConfig::with_preset(Method::Tezo, config);
    let tel = Telemetry::new(telemetry::DEFAULT_RING_CAPACITY);
    let t0 = Instant::now();
    let cold = autotune::resolve(&rt, &cfg, &tel).expect("cold resolve");
    let tune_secs = t0.elapsed().as_secs_f64();
    assert_eq!(cold.source, TuneSource::Measured);
    let t1 = Instant::now();
    let warm = autotune::resolve(&rt, &cfg, &tel).expect("warm resolve");
    let warm_secs = t1.elapsed().as_secs_f64();
    assert_eq!(warm.source, TuneSource::CacheHit);
    assert_eq!(warm.form, cold.form);

    let run_form = |form: ForwardForm| -> f64 {
        let mut cfg = TrainConfig::with_preset(Method::Tezo, config);
        cfg.steps = steps;
        cfg.forward_form = FormPolicy::Pinned(form);
        let mut params = ParamStore::load(&rt.client, &rt.manifest).expect("params");
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("rte").unwrap(), tok,
                             rt.manifest.config.seq_len, 0);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        rt.warmup_method(Method::Tezo, form).expect("warmup");
        let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder));
        let outcome = trainer.run(&mut params).expect("train");
        outcome.metrics.wall_seconds / steps as f64 * 1e3
    };
    let materialize_ms = run_form(ForwardForm::Materialize);
    let implicit_ms = run_form(ForwardForm::Implicit);
    // an independent run under the tuned winner — what `--forward-form
    // auto` dispatches after resolution
    let auto_ms = run_form(cold.form);
    let best_ms = materialize_ms.min(implicit_ms);
    println!("autotune ({config}): winner {} — auto {auto_ms:.1} ms/step vs \
              materialize {materialize_ms:.1} / implicit {implicit_ms:.1} \
              (tuned in {tune_secs:.2}s, warm resolve {:.1}us)",
             cold.form.name(), warm_secs * 1e6);
    Some(Value::obj(vec![
        ("winner", Value::str(cold.form.name())),
        ("materialize_ms_per_step", Value::f(materialize_ms)),
        ("implicit_ms_per_step", Value::f(implicit_ms)),
        ("auto_ms_per_step", Value::f(auto_ms)),
        ("auto_speedup_vs_implicit", Value::f(implicit_ms / auto_ms.max(1e-9))),
        ("auto_speedup_vs_best_pinned", Value::f(best_ms / auto_ms.max(1e-9))),
        ("cold_tune_seconds", Value::f(tune_secs)),
        ("warm_resolve_seconds", Value::f(warm_secs)),
        // one-off measurement cost spread over this run's steps
        ("tune_amortized_ms_per_step",
         Value::f(tune_secs / steps as f64 * 1e3)),
        ("trials", Value::i(cold.trials as i64)),
    ]))
}

/// PR 8 budget check: the same `tezo` run with the tracer off and on,
/// interleaved A/B with a min-of-N readout so machine drift hits both
/// arms. The snapshot asserts the <2% step-time overhead budget from
/// docs/observability.md (enabled spans are O(1) clock reads + one ring
/// write per phase; disabled telemetry is a single `Option` check).
fn bench_telemetry_overhead(config: &str, steps: usize) -> Option<Value> {
    let rt = Runtime::open(&tezo::artifacts_root().join(config)).ok()?;
    let run = |tel: &Telemetry| -> f64 {
        let mut cfg = TrainConfig::with_preset(Method::Tezo, config);
        cfg.steps = steps;
        let mut params = ParamStore::load(&rt.client, &rt.manifest).expect("params");
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("rte").unwrap(), tok,
                             rt.manifest.config.seq_len, 0);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder))
            .with_telemetry(tel.clone());
        let outcome = trainer.run(&mut params).expect("train");
        outcome.metrics.wall_seconds / steps as f64 * 1e3
    };
    // warmup: compiles the artifact set so both measured arms are pure
    // execution
    run(&Telemetry::off());
    let mut off_ms = f64::INFINITY;
    let mut on_ms = f64::INFINITY;
    for _ in 0..3 {
        off_ms = off_ms.min(run(&Telemetry::off()));
        let tel = Telemetry::new(telemetry::DEFAULT_RING_CAPACITY);
        on_ms = on_ms.min(run(&tel));
    }
    let overhead = on_ms / off_ms.max(1e-9) - 1.0;
    println!("telemetry overhead ({config}): off {off_ms:.2} ms/step, \
              on {on_ms:.2} ms/step ({:+.2}%)", overhead * 100.0);
    assert!(overhead < 0.02,
            "telemetry overhead {:.2}% exceeds the 2% budget", overhead * 100.0);
    Some(Value::obj(vec![
        ("config", Value::str(config)),
        ("steps", Value::i(steps as i64)),
        ("telemetry_off_ms_per_step", Value::f(off_ms)),
        ("telemetry_on_ms_per_step", Value::f(on_ms)),
        ("overhead_frac", Value::f(overhead)),
        ("budget_frac", Value::f(0.02)),
    ]))
}

fn bench_config(config: &str, steps: usize) {
    let rt = Runtime::open(&tezo::artifacts_root().join(config)).expect("runtime");
    let mut rep = Report::new(
        &format!("Table 8 / Fig 3(b) — ms per iteration ({config}, {} params)",
                 rt.manifest.config.n_params),
        &["ms/step", "fwd %", "update %", "sample %", "host %", "upB/step",
          "vs mezo"],
    );
    let mut mezo_ms = None;
    let mut rows = Vec::new();
    for m in METHODS {
        let mut cfg = TrainConfig::with_preset(m, config);
        cfg.steps = steps;
        let mut params = ParamStore::load(&rt.client, &rt.manifest).expect("params");
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("rte").unwrap(), tok,
                             rt.manifest.config.seq_len, 0);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        // warmup run: compiles this method's artifacts into the cache so the
        // measured run below is pure execution
        {
            let mut wcfg = cfg.clone();
            wcfg.steps = 2;
            let mut wparams = ParamStore::load(&rt.client, &rt.manifest).expect("params");
            Trainer::new(&rt, wcfg, DataSource::Task(builder.clone()))
                .run(&mut wparams)
                .expect("warmup");
        }
        let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder));
        let t0 = Instant::now();
        let outcome = trainer.run(&mut params).expect("train");
        let _total = t0.elapsed();
        let ms = outcome.metrics.wall_seconds / steps as f64 * 1e3;
        if m == Method::Mezo {
            mezo_ms = Some(ms);
        }
        let t = &outcome.metrics.timers;
        let tot = t.total_seconds().max(1e-9);
        // per-step host→device upload bytes: the prepared-call staging pool
        // dedupes the batch across sub-forwards and the seed across the
        // forward/update pair (see docs/runtime.md)
        let up_per_step = outcome.staging.upload_bytes / steps as u64;
        rows.push((m, ms,
                   t.seconds(tezo::coordinator::metrics::Phase::Forward) / tot,
                   t.seconds(tezo::coordinator::metrics::Phase::Update) / tot,
                   t.seconds(tezo::coordinator::metrics::Phase::Sampling) / tot,
                   t.seconds(tezo::coordinator::metrics::Phase::Host) / tot,
                   up_per_step));
    }
    for (m, ms, fwd, upd, smp, host, up) in rows {
        rep.add_row(m.name(), vec![
            format!("{ms:.1}"),
            format!("{:.0}%", fwd * 100.0),
            format!("{:.0}%", upd * 100.0),
            format!("{:.0}%", smp * 100.0),
            format!("{:.0}%", host * 100.0),
            format!("{up}"),
            mezo_ms.map(|base| format!("{:.2}x", ms / base)).unwrap_or_default(),
        ]);
    }
    rep.print();
    rep.write_csv(std::path::Path::new(&format!("out/table8_{config}.csv"))).ok();
    println!("note: absolute times are CPU-PJRT ({}); paper ratios are the target",
             fmt_time(1e-3).trim());
}
