//! Table 8 / Fig 3(b) reproduction: wall-clock per training iteration and
//! per-phase breakdown, per method, on the configs whose artifacts exist
//! (tiny always; small/medium when built).
//!
//! The paper's claim under test: TeZO ~ MeZO step time; TeZO-Adam clearly
//! faster than MeZO-Adam (1.5-1.6x on H100); low-rank overhead only pays
//! off as the model grows. Absolute numbers here are CPU-PJRT, the
//! *ratios* are the reproduction target.
//!
//! Run: `cargo bench --bench bench_walltime` (TEZO_BENCH_FAST=1 to shrink).

use std::time::Instant;

use tezo::benchkit::{fmt_time, Report};
use tezo::config::{Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::runtime::{ParamStore, Runtime};

const METHODS: [Method; 10] = [
    Method::Mezo, Method::Subzo, Method::Lozo, Method::Tezo,
    Method::MezoM, Method::LozoM, Method::TezoM,
    Method::MezoAdam, Method::ZoAdamu, Method::TezoAdam,
];

fn main() {
    let fast = std::env::var_os("TEZO_BENCH_FAST").is_some();
    let steps = if fast { 6 } else { 30 };
    // TEZO_BENCH_CONFIGS limits the sweep (the bigger configs cost minutes
    // of XLA compile + seconds per step on CPU)
    let configs = std::env::var("TEZO_BENCH_CONFIGS").unwrap_or_else(|_| {
        if fast { "tiny,tiny_jnp".into() } else { "tiny,tiny_jnp,small,medium".into() }
    });
    for config in configs.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let dir = tezo::artifacts_root().join(config);
        if !dir.join("manifest.json").exists() {
            println!("(skipping {config}: artifacts missing)");
            continue;
        }
        bench_config(config, steps);
    }
}

fn bench_config(config: &str, steps: usize) {
    let rt = Runtime::open(&tezo::artifacts_root().join(config)).expect("runtime");
    let mut rep = Report::new(
        &format!("Table 8 / Fig 3(b) — ms per iteration ({config}, {} params)",
                 rt.manifest.config.n_params),
        &["ms/step", "fwd %", "update %", "sample %", "host %", "upB/step",
          "vs mezo"],
    );
    let mut mezo_ms = None;
    let mut rows = Vec::new();
    for m in METHODS {
        let mut cfg = TrainConfig::with_preset(m, config);
        cfg.steps = steps;
        let mut params = ParamStore::load(&rt.client, &rt.manifest).expect("params");
        let tok = Tokenizer::new(rt.manifest.config.vocab);
        let task = Task::new(tasks::spec_by_name("rte").unwrap(), tok,
                             rt.manifest.config.seq_len, 0);
        let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
        // warmup run: compiles this method's artifacts into the cache so the
        // measured run below is pure execution
        {
            let mut wcfg = cfg.clone();
            wcfg.steps = 2;
            let mut wparams = ParamStore::load(&rt.client, &rt.manifest).expect("params");
            Trainer::new(&rt, wcfg, DataSource::Task(builder.clone()))
                .run(&mut wparams)
                .expect("warmup");
        }
        let mut trainer = Trainer::new(&rt, cfg, DataSource::Task(builder));
        let t0 = Instant::now();
        let outcome = trainer.run(&mut params).expect("train");
        let _total = t0.elapsed();
        let ms = outcome.metrics.wall_seconds / steps as f64 * 1e3;
        if m == Method::Mezo {
            mezo_ms = Some(ms);
        }
        let t = &outcome.metrics.timers;
        let tot = t.total_seconds().max(1e-9);
        // per-step host→device upload bytes: the prepared-call staging pool
        // dedupes the batch across sub-forwards and the seed across the
        // forward/update pair (see docs/runtime.md)
        let up_per_step = outcome.staging.upload_bytes / steps as u64;
        rows.push((m, ms,
                   t.seconds(tezo::coordinator::metrics::Phase::Forward) / tot,
                   t.seconds(tezo::coordinator::metrics::Phase::Update) / tot,
                   t.seconds(tezo::coordinator::metrics::Phase::Sampling) / tot,
                   t.seconds(tezo::coordinator::metrics::Phase::Host) / tot,
                   up_per_step));
    }
    for (m, ms, fwd, upd, smp, host, up) in rows {
        rep.add_row(m.name(), vec![
            format!("{ms:.1}"),
            format!("{:.0}%", fwd * 100.0),
            format!("{:.0}%", upd * 100.0),
            format!("{:.0}%", smp * 100.0),
            format!("{:.0}%", host * 100.0),
            format!("{up}"),
            mezo_ms.map(|base| format!("{:.2}x", ms / base)).unwrap_or_default(),
        ]);
    }
    rep.print();
    rep.write_csv(std::path::Path::new(&format!("out/table8_{config}.csv"))).ok();
    println!("note: absolute times are CPU-PJRT ({}); paper ratios are the target",
             fmt_time(1e-3).trim());
}
