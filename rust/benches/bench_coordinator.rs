//! L3 coordinator microbenches: the host-side work that must stay off the
//! critical path (paper target: everything outside the two forwards < 5%
//! of step time). Covers seed derivation, tau sampling, tau-space moment
//! accumulation, batch construction, JSON parsing, SVD rank probing.
//!
//! Run: `cargo bench --bench bench_coordinator`.

use tezo::benchkit::{bench, BenchOpts, Report};
use tezo::coordinator::seeds::{SeedSchedule, Stream};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::jsonx;
use tezo::rngx::normal_rng;
use tezo::tensor::{svd, Matrix};

fn main() {
    let opts = BenchOpts::from_env();
    let mut rep = Report::new(
        "L3 coordinator hot-path microbenches",
        &["median", "mean", "p95", "iters", "outliers"],
    );

    // seed schedule: one step's worth of seeds
    let sched = SeedSchedule::new(42);
    let mut step = 0u64;
    let s = bench("seed derivation (per step)", opts, || {
        let a = sched.step_seed(step);
        let b = sched.seed32(Stream::Data, step);
        std::hint::black_box((a, b));
        step += 1;
    });
    rep.add_sample(&s);

    // tau draws: 26 matrices x r=64 (the `small`-config shape of the work)
    let s = bench("tau draws (26 x r=64)", opts, || {
        for i in 0..26u64 {
            let mut g = normal_rng(i);
            let tau: Vec<f32> = (0..64).map(|_| g.next_f32()).collect();
            std::hint::black_box(tau);
        }
    });
    rep.add_sample(&s);

    // tau-space Adam accumulation (the whole TeZO-Adam optimizer step)
    let mut tau_m = vec![vec![0.0f32; 64]; 26];
    let mut tau_v = vec![vec![0.0f32; 64]; 26];
    let taus = vec![vec![0.1f32; 64]; 26];
    let s = bench("tau-space adam accumulate (26 x r=64)", opts, || {
        let kappa = 0.3f32;
        for ((m, v), t) in tau_m.iter_mut().zip(tau_v.iter_mut()).zip(taus.iter()) {
            for i in 0..t.len() {
                m[i] = 0.9 * m[i] + 0.1 * kappa * t[i];
                v[i] = 0.99 * v[i] + 0.01 * kappa * kappa * t[i] * t[i];
            }
        }
        std::hint::black_box((&tau_m, &tau_v));
    });
    rep.add_sample(&s);

    // batch construction (seq 128, batch 8)
    let task = Task::new(tasks::spec_by_name("rte").unwrap(), Tokenizer::new(2048), 128, 0);
    let bb = BatchBuilder::new(task, 8, 16);
    let mut bstep = 0u64;
    let s = bench("train batch build (8 x 128)", opts, || {
        let b = bb.train_batch(0, bstep);
        std::hint::black_box(b);
        bstep += 1;
    });
    rep.add_sample(&s);

    // manifest-scale JSON parse
    let manifest_path = tezo::artifacts_root().join("tiny/manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        let s = bench("manifest.json parse", opts, || {
            let v = jsonx::parse(&text).unwrap();
            std::hint::black_box(v);
        });
        rep.add_sample(&s);
    }

    // Eq.(7) rank probe on a 512x512 weight
    let mut g = normal_rng(5);
    let u = Matrix::randn(512, 16, &mut g);
    let v = Matrix::randn(512, 16, &mut g);
    let mut w = u.matmul(&v.transpose()).unwrap();
    let noise = Matrix::randn(512, 512, &mut g);
    w.axpy(0.02, &noise).unwrap();
    let s = bench("rank_at_threshold (512x512, k=64)", opts, || {
        let r = svd::rank_at_threshold(&w, 0.25, 64, 7).unwrap();
        std::hint::black_box(r);
    });
    rep.add_sample(&s);

    rep.print();
    rep.write_csv(std::path::Path::new("out/coordinator_microbench.csv")).ok();
}
