//! Table 2 reproduction: total sampled random elements per method.
//!
//! Two parts:
//!  1. closed-form table at the paper's scale (one 4096x4096 weight,
//!     T = 15000, r = 64) — the exact Table-2 rows;
//!  2. measured host-RNG throughput for the draw patterns (what sampling
//!     actually costs per step at each count).
//!
//! Run: `cargo bench --bench bench_table2_sampling` (TEZO_BENCH_FAST=1 for
//! a quick pass).

use tezo::benchkit::{bench, BenchOpts, Report};
use tezo::coordinator::counter::closed_form;
use tezo::rngx::normal_rng;

fn main() {
    closed_form_table();
    measured_sampling_cost();
}

fn closed_form_table() {
    let (m, n, r, t, nu) = (4096u64, 4096u64, 64u64, 15_000u64, 500u64);
    let mut rep = Report::new(
        "Table 2 — total sampled elements (one 4096x4096 weight, T=15000, r=64)",
        &["total elements", "per-step avg", "vs MeZO"],
    );
    let mezo = closed_form::mezo(m, n, t);
    let rows = [
        ("MeZO", mezo),
        ("SubZO (nu=500)", closed_form::subzo(m, n, r, t, nu)),
        ("LOZO (nu=50)", closed_form::lozo(m, n, r, t, 50)),
        ("TeZO", closed_form::tezo(m, n, r, t)),
    ];
    for (name, total) in rows {
        rep.add_row(name, vec![
            format!("{total}"),
            format!("{:.1}", total as f64 / t as f64),
            format!("{:.5}x", total as f64 / mezo as f64),
        ]);
    }
    rep.print();
    rep.write_csv(std::path::Path::new("out/table2_closed_form.csv")).ok();
}

fn measured_sampling_cost() {
    let opts = BenchOpts::from_env();
    let (m, n, r) = (1024usize, 1024usize, 64usize);
    let mut rep = Report::new(
        "Table 2 — measured host sampling cost per step (1024x1024, r=64)",
        &["median", "mean", "p95", "iters", "outliers"],
    );
    let mut gen = normal_rng(1);
    let mut sink = 0.0f32;

    // MeZO: m*n dense draws
    let s = bench("mezo: m*n draws", opts, || {
        for _ in 0..m * n {
            sink += gen.next_f32();
        }
    });
    rep.add_sample(&s);
    // LOZO: n*r draws (V only)
    let s = bench("lozo: n*r draws", opts, || {
        for _ in 0..n * r {
            sink += gen.next_f32();
        }
    });
    rep.add_sample(&s);
    // SubZO: r*r draws
    let s = bench("subzo: r*r draws", opts, || {
        for _ in 0..r * r {
            sink += gen.next_f32();
        }
    });
    rep.add_sample(&s);
    // TeZO: r draws
    let s = bench("tezo: r draws", opts, || {
        for _ in 0..r {
            sink += gen.next_f32();
        }
    });
    rep.add_sample(&s);
    std::hint::black_box(sink);
    rep.print();
    rep.write_csv(std::path::Path::new("out/table2_measured.csv")).ok();
}
