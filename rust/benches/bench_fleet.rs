//! Fleet scaling benchmark: (1) the pure synchronization overhead of the
//! scalar ticket protocol (no runtime needed — echo workers), and (2) when
//! the tiny artifacts are present, end-to-end `FleetTrainer` steps at 1/2/4
//! workers against the single-process trainer baseline, plus the
//! bytes-communicated table vs a hypothetical gradient all-reduce.
//!
//! Run: `cargo bench --bench bench_fleet` (TEZO_BENCH_FAST=1 for CI).

use std::sync::mpsc;
use std::time::Instant;

use tezo::benchkit::{bench, fmt_time, write_json_value, BenchOpts, Report};
use tezo::config::{FleetConfig, Method, TrainConfig};
use tezo::coordinator::trainer::{DataSource, Trainer};
use tezo::data::{tasks, BatchBuilder, Task, Tokenizer};
use tezo::fleet::protocol::{aggregate_two_point, Command, Event, Ticket};
use tezo::fleet::{task_job_factory, FleetTrainer};
use tezo::jsonx::Value;
use tezo::memmodel::comm;
use tezo::runtime::{Manifest, ParamStore, Runtime};

/// One synchronization round against W echo workers: broadcast a ticket,
/// collect W results, aggregate, broadcast the kappa, collect W acks.
/// This is everything the fleet adds on top of the forward itself.
fn protocol_round_trip(rep: &mut Report, opts: BenchOpts, workers: usize) {
    let (etx, erx) = mpsc::channel::<Event>();
    let mut cmd_txs = Vec::new();
    let mut handles = Vec::new();
    for w in 0..workers {
        let (ctx, crx) = mpsc::channel::<Command>();
        cmd_txs.push(ctx);
        let etx = etx.clone();
        handles.push(std::thread::spawn(move || {
            while let Ok(cmd) = crx.recv() {
                match cmd {
                    Command::Forward(t) => {
                        let _ = etx.send(Event::TwoPoint {
                            worker: w,
                            step: t.step,
                            sub: t.sub,
                            f_plus: 1.0 + w as f32,
                            f_minus: 1.0,
                            forward_secs: 0.0,
                        });
                    }
                    Command::Apply { ticket, .. } | Command::Skip { ticket } => {
                        let _ = etx.send(Event::Applied {
                            worker: w,
                            step: ticket.step,
                            sub: ticket.sub,
                            update_secs: 0.0,
                        });
                    }
                    Command::Stop => return,
                    Command::Eval { .. }
                    | Command::Checkpoint { .. }
                    | Command::CatchUp(_) => {}
                }
            }
        }));
    }
    drop(etx);

    let mut step = 0u64;
    let s = bench(&format!("protocol round trip (W={workers})"), opts, || {
        let ticket = Ticket { step, sub: 0, perturb_seed: 1 };
        for tx in &cmd_txs {
            tx.send(Command::Forward(ticket)).unwrap();
        }
        let mut slots = vec![(0.0f32, 0.0f32); workers];
        for _ in 0..workers {
            match erx.recv().unwrap() {
                Event::TwoPoint { worker, f_plus, f_minus, .. } => {
                    slots[worker] = (f_plus, f_minus);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        let (fp, fm) = aggregate_two_point(&slots);
        let kappa = (fp - fm) / 2e-3;
        for tx in &cmd_txs {
            tx.send(Command::Apply { ticket, kappa }).unwrap();
        }
        for _ in 0..workers {
            let _ = erx.recv().unwrap();
        }
        step += 1;
    });
    rep.add_sample(&s);

    for tx in &cmd_txs {
        let _ = tx.send(Command::Stop);
    }
    for h in handles {
        let _ = h.join();
    }
}

fn fleet_scaling(rep: &mut Report, dir: &std::path::Path, steps: usize) {
    // single-process baseline
    let rt = Runtime::open(dir).expect("open runtime");
    let mut cfg = TrainConfig::with_preset(Method::Tezo, "tiny");
    cfg.steps = steps;
    let tok = Tokenizer::new(rt.manifest.config.vocab);
    let task = Task::new(tasks::spec_by_name("sst2").unwrap(), tok,
                         rt.manifest.config.seq_len, 0);
    let builder = BatchBuilder::new(task, rt.manifest.config.batch, 16);
    let mut params = ParamStore::load(&rt.client, &rt.manifest).unwrap();
    let t0 = Instant::now();
    Trainer::new(&rt, cfg.clone(), DataSource::Task(builder))
        .run(&mut params)
        .unwrap();
    let base = t0.elapsed().as_secs_f64() / steps as f64;
    rep.add_row("trainer (1 proc)",
                vec![fmt_time(base), "-".into(), "-".into(), "-".into()]);
    drop(rt);

    let n_params = Manifest::load(dir).unwrap().config.n_params as u64;
    for workers in [1usize, 2, 4] {
        // eval_n = 0: pure step throughput, no eval rounds
        let factory = task_job_factory("sst2".to_string(), 0, 16, 0, None);
        let mut ft = FleetTrainer::new(FleetConfig::new(workers), cfg.clone(),
                                       dir.to_path_buf(), factory);
        let t0 = Instant::now();
        let out = ft.run().expect("fleet run");
        let per_step = t0.elapsed().as_secs_f64() / steps as f64;
        let scalar = out.fleet.comm.total_bytes();
        let allreduce =
            comm::gradient_allreduce_step_bytes(n_params, workers as u64)
                * steps as u64;
        rep.add_row(
            &format!("fleet W={workers}"),
            vec![
                fmt_time(per_step),
                format!("{:.3}", out.fleet.straggler_factor()),
                format!("{scalar}"),
                if workers > 1 {
                    format!("{:.1e}x", allreduce as f64 / scalar.max(1) as f64)
                } else {
                    "-".into()
                },
            ],
        );
    }
}

/// Wire bytes per step x worker count: the logical scalar-ticket payload
/// (what `CommStats::total_bytes` counts) vs the framed bytes the TCP
/// transport actually moves (length prefix + tag + result metadata; what
/// `CommStats::total_wire_bytes` counts). Both are per-worker-linear and
/// model-size-independent — the row pins the framing overhead ratio into
/// the perf trajectory.
fn wire_bytes_table() -> Value {
    let mut rep = Report::new(
        "wire bytes per step (q=1 perturbation)",
        &["logical B", "framed B", "overhead", "vs all-reduce (1M params)"],
    );
    let mut rows: Vec<Value> = Vec::new();
    for workers in [1u64, 2, 4, 8] {
        let logical = comm::zo_scalar_step_bytes(workers, 1);
        let framed = comm::zo_scalar_step_wire_bytes(workers, 1);
        let allreduce = comm::gradient_allreduce_step_bytes(1_000_000, workers);
        rep.add_row(&format!("W={workers}"), vec![
            format!("{logical}"),
            format!("{framed}"),
            format!("{:.2}x", framed as f64 / logical.max(1) as f64),
            format!("{:.1e}x", allreduce as f64 / framed.max(1) as f64),
        ]);
        rows.push(Value::obj(vec![
            ("workers", Value::i(workers as i64)),
            ("logical_bytes_per_step", Value::i(logical as i64)),
            ("framed_bytes_per_step", Value::i(framed as i64)),
        ]));
    }
    rep.print();
    rep.write_csv(std::path::Path::new("out/fleet_wire_bytes.csv")).ok();
    Value::obj(vec![
        ("per_worker_count", Value::arr(rows)),
        ("frame_header_bytes", Value::i(comm::FRAME_HEADER_BYTES as i64)),
        ("result_meta_bytes", Value::i(comm::RESULT_META_BYTES as i64)),
    ])
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut rep = Report::new(
        "fleet protocol sync overhead",
        &["median", "mean", "p95", "iters", "outliers"],
    );
    for workers in [1usize, 2, 4, 8] {
        protocol_round_trip(&mut rep, opts, workers);
    }
    rep.print();
    rep.write_csv(std::path::Path::new("out/fleet_protocol_bench.csv")).ok();

    let wire = wire_bytes_table();
    let doc = Value::obj(vec![
        ("snapshot", Value::str("fleet wire bytes: logical vs framed")),
        ("wire_bytes", wire),
    ]);
    let path = std::path::PathBuf::from("out/BENCH_PR7.json");
    match write_json_value(&path, &doc) {
        Ok(()) => println!("wire-bytes snapshot -> {}", path.display()),
        Err(e) => println!("(snapshot write failed: {e})"),
    }

    let dir = tezo::artifacts_root().join("tiny");
    if dir.join("manifest.json").exists() {
        let steps = if std::env::var_os("TEZO_BENCH_FAST").is_some() { 4 } else { 12 };
        let mut rep = Report::new(
            "fleet scaling on tiny artifacts",
            &["sec/step", "straggler", "comm bytes", "vs all-reduce"],
        );
        fleet_scaling(&mut rep, &dir, steps);
        rep.print();
        rep.write_csv(std::path::Path::new("out/fleet_scaling.csv")).ok();
    } else {
        eprintln!("artifacts/tiny missing: skipping end-to-end fleet scaling");
    }
}
