//! L1 kernel microbenches over the standalone per-shape artifacts
//! (`artifacts/kernels/`): fused TeZO perturb (rank-r CPD + axpy) vs the
//! dense MeZO perturb (in-HLO normal + axpy), per weight shape.
//!
//! This isolates the perturbation phase the paper's Fig 3(b) decomposes:
//! at equal shapes the TeZO kernel does O(r) FLOPs/byte on the weight
//! stream while the dense kernel pays the full RNG + read-write sweep.
//!
//! The second section isolates the *dispatch* layer: identical kernel
//! executions driven through the legacy positional builder (host args
//! re-validated and re-uploaded every call) vs a prepared call with
//! arena staging (plan resolved once, host args uploaded once per step) —
//! the per-step host→device byte counters quantify the reduction.
//!
//! Run: `cargo bench --bench bench_kernels`.

use tezo::benchkit::{bench, BenchOpts, Report};
use tezo::runtime::{ArgValue, Runtime};
use tezo::rngx::normal_vec;

const SHAPES: [(usize, usize, usize); 7] = [
    (256, 256, 8), (256, 1024, 8), (512, 512, 16), (512, 2048, 16),
    (1024, 1024, 32), (1024, 4096, 32), (2048, 2048, 64),
];

fn main() {
    let dir = tezo::artifacts_root().join("kernels");
    if !dir.join("manifest.json").exists() {
        println!("(skipping: artifacts/kernels missing — run `make artifacts-kernels`)");
        return;
    }
    let rt = Runtime::open(&dir).expect("runtime");
    let opts = BenchOpts::from_env();
    let mut rep = Report::new(
        "L1 kernel microbench — fused perturb, CPU-PJRT",
        &["median", "mean", "p95", "iters", "outliers"],
    );

    for (m, n, r) in SHAPES {
        let w = normal_vec(1, m * n);
        let u = normal_vec(2, m * r);
        let v = normal_vec(3, n * r);
        let tau = normal_vec(4, r);
        // stage inputs once as device buffers: the bench then measures pure
        // kernel execution, not host staging
        let wb = rt.client.buffer_from_host_buffer(&w, &[m, n], None).unwrap();
        let ub = rt.client.buffer_from_host_buffer(&u, &[m, r], None).unwrap();
        let vb = rt.client.buffer_from_host_buffer(&v, &[n, r], None).unwrap();
        let tb = rt.client.buffer_from_host_buffer(&tau, &[r], None).unwrap();
        let rho = rt.client.buffer_from_host_buffer(&[1e-3f32], &[], None).unwrap();

        let tezo_name = format!("kernel_tezo_perturb_{m}x{n}_r{r}");
        rt.executable(&tezo_name).unwrap(); // compile outside timing
        let s = bench(&format!("tezo {m}x{n} r{r}"), opts, || {
            let out = rt.call(&tezo_name).unwrap()
                .arg(ArgValue::Buf(&wb)).unwrap()
                .arg(ArgValue::Buf(&ub)).unwrap()
                .arg(ArgValue::Buf(&vb)).unwrap()
                .arg(ArgValue::Buf(&tb)).unwrap()
                .arg(ArgValue::Buf(&rho)).unwrap()
                .run().unwrap();
            std::hint::black_box(out);
        });
        rep.add_sample(&s);

        let mezo_name = format!("kernel_mezo_perturb_{m}x{n}");
        rt.executable(&mezo_name).unwrap();
        let seed = rt.client.buffer_from_host_buffer(&[7u32], &[], None).unwrap();
        let s = bench(&format!("mezo {m}x{n}"), opts, || {
            let out = rt.call(&mezo_name).unwrap()
                .arg(ArgValue::Buf(&wb)).unwrap()
                .arg(ArgValue::Buf(&seed)).unwrap()
                .arg(ArgValue::Buf(&rho)).unwrap()
                .run().unwrap();
            std::hint::black_box(out);
        });
        rep.add_sample(&s);
    }
    rep.print();
    rep.write_csv(std::path::Path::new("out/kernel_microbench.csv")).ok();
    bench_dispatch(&rt, opts);
}

/// Dispatch-layer comparison on one mid-size shape: legacy per-call
/// staging vs prepared calls + step-arena staging, byte-counted.
fn bench_dispatch(rt: &Runtime, opts: BenchOpts) {
    let (m, n, r) = (1024, 1024, 32);
    let name = format!("kernel_tezo_perturb_{m}x{n}_r{r}");
    let w = normal_vec(11, m * n);
    let u = normal_vec(12, m * r);
    let v = normal_vec(13, n * r);
    let tau = normal_vec(14, r);
    let wb = rt.client.buffer_from_host_buffer(&w, &[m, n], None).unwrap();
    let ub = rt.client.buffer_from_host_buffer(&u, &[m, r], None).unwrap();
    let vb = rt.client.buffer_from_host_buffer(&v, &[n, r], None).unwrap();
    rt.executable(&name).unwrap(); // compile outside timing

    let mut rep = Report::new(
        "Dispatch layer — legacy positional staging vs prepared + arena",
        &["median", "mean", "p95", "iters", "outliers"],
    );

    // legacy path: tau + rho validated against the manifest and uploaded
    // fresh on EVERY call (how every driver dispatched before the
    // prepared-call refactor)
    let legacy_calls = std::cell::Cell::new(0u64);
    let before = rt.stage().stats();
    let s = bench("legacy CallBuilder (re-stage tau+rho)", opts, || {
        legacy_calls.set(legacy_calls.get() + 1);
        let out = rt.call(&name).unwrap()
            .arg(ArgValue::Buf(&wb)).unwrap()
            .arg(ArgValue::Buf(&ub)).unwrap()
            .arg(ArgValue::Buf(&vb)).unwrap()
            .arg(ArgValue::F32(&tau)).unwrap()
            .arg(ArgValue::ScalarF32(1e-3)).unwrap()
            .run().unwrap();
        std::hint::black_box(out);
    });
    rep.add_sample(&s);
    let legacy = rt.stage().stats().since(&before);

    // prepared path: same executions, but the plan is resolved once and
    // tau/rho live in the staging pool — uploaded on the first call of the
    // "step", reused by every later one
    let prepared_calls = std::cell::Cell::new(0u64);
    let before = rt.stage().stats();
    let s = bench("prepared + StepArena (pool-staged)", opts, || {
        prepared_calls.set(prepared_calls.get() + 1);
        let arena = rt.step_arena(0);
        let mut call = rt.prepared(&name).unwrap();
        call.bind_buf("tensor", "w", &wb).unwrap();
        call.bind_buf("factor_u", "u", &ub).unwrap();
        call.bind_buf("factor_v", "v", &vb).unwrap();
        call.bind_f32("tau", "tau", &tau, &arena).unwrap();
        call.bind_scalar_f32("rho", 1e-3, &arena).unwrap();
        let out = call.run().unwrap();
        std::hint::black_box(out);
    });
    rep.add_sample(&s);
    let prepared = rt.stage().stats().since(&before);

    rep.print();
    // the two bench runs execute different iteration counts (adaptive
    // budget), so compare per-call averages, not totals
    let legacy_per_call = legacy.upload_bytes as f64 / legacy_calls.get().max(1) as f64;
    let prepared_per_call =
        prepared.upload_bytes as f64 / prepared_calls.get().max(1) as f64;
    println!("host->device upload bytes per call: legacy {legacy_per_call:.1} \
              vs prepared {prepared_per_call:.3} ({:.0}x less; {} bytes \
              served from the pool)",
             legacy_per_call / prepared_per_call.max(1e-9),
             prepared.reused_bytes);
}
