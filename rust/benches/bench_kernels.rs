//! L1 kernel microbenches over the standalone per-shape artifacts
//! (`artifacts/kernels/`): fused TeZO perturb (rank-r CPD + axpy) vs the
//! dense MeZO perturb (in-HLO normal + axpy), per weight shape.
//!
//! This isolates the perturbation phase the paper's Fig 3(b) decomposes:
//! at equal shapes the TeZO kernel does O(r) FLOPs/byte on the weight
//! stream while the dense kernel pays the full RNG + read-write sweep.
//!
//! Run: `cargo bench --bench bench_kernels`.

use tezo::benchkit::{bench, BenchOpts, Report};
use tezo::runtime::{ArgValue, Runtime};
use tezo::rngx::normal_vec;

const SHAPES: [(usize, usize, usize); 7] = [
    (256, 256, 8), (256, 1024, 8), (512, 512, 16), (512, 2048, 16),
    (1024, 1024, 32), (1024, 4096, 32), (2048, 2048, 64),
];

fn main() {
    let dir = tezo::artifacts_root().join("kernels");
    if !dir.join("manifest.json").exists() {
        println!("(skipping: artifacts/kernels missing — run `make artifacts-kernels`)");
        return;
    }
    let rt = Runtime::open(&dir).expect("runtime");
    let opts = BenchOpts::from_env();
    let mut rep = Report::new(
        "L1 kernel microbench — fused perturb, CPU-PJRT",
        &["median", "mean", "p95", "iters", "outliers"],
    );

    for (m, n, r) in SHAPES {
        let w = normal_vec(1, m * n);
        let u = normal_vec(2, m * r);
        let v = normal_vec(3, n * r);
        let tau = normal_vec(4, r);
        // stage inputs once as device buffers: the bench then measures pure
        // kernel execution, not host staging
        let wb = rt.client.buffer_from_host_buffer(&w, &[m, n], None).unwrap();
        let ub = rt.client.buffer_from_host_buffer(&u, &[m, r], None).unwrap();
        let vb = rt.client.buffer_from_host_buffer(&v, &[n, r], None).unwrap();
        let tb = rt.client.buffer_from_host_buffer(&tau, &[r], None).unwrap();
        let rho = rt.client.buffer_from_host_buffer(&[1e-3f32], &[], None).unwrap();

        let tezo_name = format!("kernel_tezo_perturb_{m}x{n}_r{r}");
        rt.executable(&tezo_name).unwrap(); // compile outside timing
        let s = bench(&format!("tezo {m}x{n} r{r}"), opts, || {
            let out = rt.call(&tezo_name).unwrap()
                .arg(ArgValue::Buf(&wb)).unwrap()
                .arg(ArgValue::Buf(&ub)).unwrap()
                .arg(ArgValue::Buf(&vb)).unwrap()
                .arg(ArgValue::Buf(&tb)).unwrap()
                .arg(ArgValue::Buf(&rho)).unwrap()
                .run().unwrap();
            std::hint::black_box(out);
        });
        rep.add_sample(&s);

        let mezo_name = format!("kernel_mezo_perturb_{m}x{n}");
        rt.executable(&mezo_name).unwrap();
        let seed = rt.client.buffer_from_host_buffer(&[7u32], &[], None).unwrap();
        let s = bench(&format!("mezo {m}x{n}"), opts, || {
            let out = rt.call(&mezo_name).unwrap()
                .arg(ArgValue::Buf(&wb)).unwrap()
                .arg(ArgValue::Buf(&seed)).unwrap()
                .arg(ArgValue::Buf(&rho)).unwrap()
                .run().unwrap();
            std::hint::black_box(out);
        });
        rep.add_sample(&s);
    }
    rep.print();
    rep.write_csv(std::path::Path::new("out/kernel_microbench.csv")).ok();
}
