//! Memory reproductions: Table 7, Table 9, Fig 1(c), Fig 3(a) from the
//! analytic model, plus a measured cross-check: RSS growth of this process
//! when the tiny/small runtime allocates each method's optimizer state.
//!
//! Run: `cargo bench --bench bench_memory`.

use tezo::config::Method;
use tezo::benchkit::Report;
use tezo::memmodel::{tables, usage, opt};

fn main() {
    tables::table7().print();
    tables::table7().write_csv(std::path::Path::new("out/table7.csv")).ok();
    tables::table9().print();
    tables::table9().write_csv(std::path::Path::new("out/table9.csv")).ok();
    tables::fig1c().print();
    tables::fig1c().write_csv(std::path::Path::new("out/fig1c.csv")).ok();
    fig3a();
    measured_state_cross_check();
}

/// Fig 3(a): the OPT-13B bar chart (params + state per method).
fn fig3a() {
    let l = opt("13b");
    let mut rep = Report::new(
        "Fig 3(a) — OPT-13B memory by method (GiB)",
        &["total", "vs zero-shot"],
    );
    let zs = usage::zero_shot(&l).total() as f64;
    for m in [Method::Mezo, Method::Subzo, Method::Lozo, Method::Tezo,
              Method::MezoM, Method::LozoM, Method::TezoM,
              Method::MezoAdam, Method::ZoAdamu, Method::TezoAdam] {
        let t = usage::memory_usage(&l, m).total();
        rep.add_row(m.name(), vec![
            format!("{:.2} G", t as f64 / (1u64 << 30) as f64),
            format!("{:.3}x", t as f64 / zs),
        ]);
    }
    rep.print();
    rep.write_csv(std::path::Path::new("out/fig3a.csv")).ok();
}

/// Measured cross-check on the real runtime: allocate each driver against
/// the tiny artifacts and report its self-declared resident state. The
/// *ordering* must match the analytic model (the integration test asserts
/// it; here we print the numbers next to the model's).
fn measured_state_cross_check() {
    let dir = tezo::artifacts_root().join("tiny");
    if !dir.join("manifest.json").exists() {
        println!("(skipping measured cross-check: artifacts/tiny missing)");
        return;
    }
    let rt = tezo::runtime::Runtime::open(&dir).expect("runtime");
    let seeds = tezo::coordinator::SeedSchedule::new(0);
    let mut rep = Report::new(
        "Measured optimizer-state bytes (tiny runtime) vs analytic model",
        &["driver bytes", "model bytes (optlite-tiny)"],
    );
    let layout = tezo::memmodel::layout::optlite("tiny");
    for m in [Method::Mezo, Method::Lozo, Method::Subzo, Method::Tezo,
              Method::TezoM, Method::TezoAdam, Method::MezoM, Method::MezoAdam] {
        let cfg = tezo::config::TrainConfig { method: m, ..Default::default() };
        let driver = tezo::coordinator::build_optimizer(&rt, &cfg, &seeds).expect("driver");
        let model = usage::memory_usage(&layout, m);
        rep.add_row(m.name(), vec![
            format!("{}", driver.state_bytes()),
            format!("{}", model.optimizer_state + model.zo_state),
        ]);
    }
    rep.print();
    rep.write_csv(std::path::Path::new("out/state_cross_check.csv")).ok();
}
