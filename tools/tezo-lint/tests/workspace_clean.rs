//! The committed workspace must be clean under `--deny-all`: every
//! finding either fixed or carried in `lint/allowlist.txt` with a
//! justification. This is the same check CI's invariants job runs via
//! `cargo tezo-lint`; keeping it as a test means `cargo test
//! --manifest-path tools/tezo-lint/Cargo.toml` catches a regression
//! before the workflow does.

use tezo_lint::{finalize, findings, has_errors, load_manifests, load_sources,
                run_artifact_lint, run_code_lint, Config};

fn repo_root() -> std::path::PathBuf {
    // tools/tezo-lint -> repo root
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    dir.canonicalize().unwrap_or(dir)
}

#[test]
fn workspace_is_clean_under_deny_all() {
    let cfg = Config::new(repo_root());
    let files = load_sources(&cfg).expect("load sources");
    assert!(
        files.iter().any(|f| f.path.starts_with("rust/src")),
        "scan roots resolved no rust/src files — repo root detection broke"
    );
    let manifests = load_manifests(&cfg).expect("load manifests");
    assert!(!manifests.is_empty(), "no artifacts/*/manifest.json found");

    let mut all = run_code_lint(&files);
    all.extend(run_artifact_lint(&files, &manifests));
    let all = finalize(&cfg, all);

    if has_errors(&all) {
        panic!(
            "workspace not clean under --deny-all:\n{}",
            findings::render_text(&all)
        );
    }
}

#[test]
fn artifact_lint_alone_is_clean() {
    let cfg = Config::new(repo_root());
    let files = load_sources(&cfg).expect("load sources");
    let manifests = load_manifests(&cfg).expect("load manifests");
    let arts = finalize(&cfg, run_artifact_lint(&files, &manifests));
    if has_errors(&arts) {
        panic!(
            "artifact contract drift:\n{}",
            findings::render_text(&arts)
        );
    }
}
