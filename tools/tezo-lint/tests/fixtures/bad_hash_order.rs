// Fixture: ordering-determinism violations.

use std::collections::HashMap;

fn hash_order_sum(m: &HashMap<u32, f32>) -> f32 {
    let mut total = 0.0;
    for (_, v) in m.iter() {
        total += v; // TZ-DET001: hash order feeds float accumulation
    }
    total
}

fn nan_unsafe_sort(v: &mut Vec<f32>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap()); // TZ-DET002
}

fn sorted_emission(m: &HashMap<u32, f32>) -> f32 {
    let mut keys: Vec<u32> = m.keys().copied().collect();
    keys.sort_unstable();
    let mut total = 0.0;
    for k in keys {
        total += m[&k]; // fine: iteration order fixed by the sort
    }
    total
}
