// Fixture: telemetry/clock discipline violations (TZ-OBS001). Never
// compiled — parsed by the lint fixture tests, which assert the exact
// finding counts.

fn raw_clock() -> f64 {
    let t0 = Instant::now(); // TZ-OBS001 (raw clock outside telemetry/)
    work();
    t0.elapsed().as_secs_f64() // fine: pure timing, no obs sink
}

fn steering(tel: &Telemetry, h: &LatencyHist) {
    let kappa = tel.now_ns() as f64 * 1e-9; // TZ-OBS001 (readout -> kappa)
    let frame = encode_frame(h.p99_ns()); // TZ-OBS001 (readout -> wire frame)
    send(kappa, frame);
}

fn observing(tel: &Telemetry, kappa: f64, step: i64) {
    tel.counter("step", "kappa", kappa, step); // fine: write direction
}
