// Fixture: raw durable-IO violations on a hot path. This file is never
// compiled — parsed by the lint fixture tests, which assert the exact
// finding counts.

fn save_descriptor(path: &Path, text: &str) -> std::io::Result<()> {
    std::fs::write(path, text) // TZ-IO001: torn-file window, no fsync
}

fn open_log(path: &Path) -> std::io::Result<File> {
    File::create(path) // TZ-IO001: truncates in place, not crash-safe
}

fn read_side_is_fine(path: &Path) -> std::io::Result<Vec<u8>> {
    std::fs::create_dir_all(path)?;
    std::fs::read(path)
}

mod helpers {
    // durable seam calls stay clean
    fn good(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
        crate::runtime::durable::write_atomic(path, bytes)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_write_raw() {
        std::fs::write("t.bin", b"x").unwrap();
        let _ = File::create("u.bin").unwrap();
    }
}
