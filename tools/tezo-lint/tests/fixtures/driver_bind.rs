// Fixture: a driver forward pass in the repo's prepared-call idiom.
// Checked against manifest_ok.json (clean) and manifest_renamed.json
// (seed slot renamed → TZ-ART002).

fn forward(ctx: &mut Ctx, seed: u32) -> Result<(f32, f32)> {
    let mut call = ctx.rt.prepared("mezo_loss_pm")?;
    call.bind_bufs("param", ctx.params.bufs())?;
    call.bind_i32("batch", "tokens", &ctx.batch.tokens, ctx.arena)?;
    call.bind_scalar_u32("seed", seed, ctx.arena)?;
    call.bind_scalar_f32("rho", ctx.cfg.rho, ctx.arena)?;
    let out = call.run()?;
    Ok((out[0], out[1]))
}
