// Fixture: hot-path panic violations (lint tests load this under a
// runtime/ path so the hot-path rules apply).

fn unwraps(x: Option<u32>, y: Result<u32, ()>) -> u32 {
    x.unwrap() + y.expect("y must be set") // TZ-PANIC001 x2
}

fn diverging(kind: u8) -> u32 {
    match kind {
        0 => panic!("bad kind"),     // TZ-PANIC001
        1 => unreachable!("no path"), // TZ-PANIC001
        _ => 0,
    }
}

fn unguarded(v: &[f32], i: usize) -> f32 {
    v[i] // TZ-PANIC002: no len/get/assert discipline in this fn
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        let v = vec![1.0f32];
        assert_eq!(v[0], Some(1.0f32).unwrap()); // exempt: test code
    }
}
