// TZ-TUNE001 fixture: raw forward-form string literals in dispatch code.
// Never compiled — lexed by the linter under a synthetic non-exempt path.

fn pick_form(shape: &str) -> &'static str {
    // denied: the form is hardcoded instead of resolved via the table
    if shape == "small" { "materialize" } else { "implicit" }
}

fn warmup(rt: &Runtime) {
    // denied: policy word spelled instead of FormPolicy::parse
    let policy = "auto";
    // denied: the legacy aliases count too
    rt.warm("materialized");
    rt.warm("dense");
    let _ = policy;
}

fn fine(rt: &Runtime) {
    // artifact names and prose mentioning forms are NOT exact matches
    rt.warm("tezo_loss_pm_implicit");
    help("two-point loss form: auto | implicit | materialize");
}

#[cfg(test)]
mod tests {
    #[test]
    fn masked() {
        // test code may spell the tags (manifest round-trip assertions)
        assert_eq!(tag(), "implicit");
        assert_eq!(other(), "materialize");
    }
}
