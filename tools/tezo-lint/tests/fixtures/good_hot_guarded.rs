// Fixture: hot-path code with visible bounds discipline and typed
// errors — zero findings expected under a runtime/ path.

fn checked_sum(v: &[f32], n: usize) -> Result<f32, String> {
    if n > v.len() {
        return Err(format!("n {n} exceeds {}", v.len()));
    }
    let mut total = 0.0f64;
    for x in &v[..n] {
        total += *x as f64;
    }
    Ok(total as f32)
}

fn paired(a: &[f32], b: &[f32]) -> f32 {
    debug_assert!(a.len() == b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn lookup(v: &[f32], i: usize) -> Option<f32> {
    v.get(i).copied()
}
