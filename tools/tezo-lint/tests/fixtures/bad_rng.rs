// Fixture: RNG/time discipline violations. Never compiled — parsed by
// the lint fixture tests, which assert the exact finding counts.

fn ambient_entropy() -> u64 {
    let mut r = rand::thread_rng(); // TZ-RNG001 x2 (`rand`, `thread_rng`)
    r.next_u64()
}

fn wall_clock_id() -> u64 {
    let t = SystemTime::now(); // TZ-RNG002
    t.duration_since(UNIX_EPOCH).unwrap().as_secs() // TZ-RNG002 (UNIX_EPOCH)
}

fn time_seeded() -> u64 {
    let start = Instant::now(); // TZ-OBS001 (raw clock outside telemetry/)
    work();
    let seed = start.elapsed().as_nanos() as u64; // TZ-RNG003 x2
    seed
}

fn honest_timing() -> f64 {
    let start = Instant::now(); // TZ-OBS001 (raw clock outside telemetry/)
    work();
    start.elapsed().as_secs_f64() // fine: no seed sink in the statement
}
