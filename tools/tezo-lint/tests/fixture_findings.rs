//! Fixture tests: each finding class demonstrated on a known-bad snippet
//! with exact counts and codes, and known-good snippets staying clean.
//! The fixtures are plain text to the linter (they are never compiled),
//! loaded under synthetic paths so hot-path classification is explicit.

use tezo_lint::findings::{Code, Finding};
use tezo_lint::manifestx::ManifestContracts;
use tezo_lint::rules;
use tezo_lint::source::SourceFile;

fn code_lint(path: &str, src: &str) -> Vec<Finding> {
    let f = SourceFile::new(path.into(), src);
    let mut out = Vec::new();
    rules::rng_time::check(&f, &mut out);
    rules::determinism::check(&f, &mut out);
    rules::panics::check(&f, &mut out);
    rules::obs::check(&f, &mut out);
    rules::tune::check(&f, &mut out);
    rules::io::check(&f, &mut out);
    out
}

fn count(fs: &[Finding], code: Code) -> usize {
    fs.iter().filter(|f| f.code == code).count()
}

#[test]
fn bad_rng_fixture_exact_counts() {
    // non-hot path: only the RNG rules should fire
    let fs = code_lint("rust/src/tensor/fixture_rng.rs",
                       include_str!("fixtures/bad_rng.rs"));
    assert_eq!(count(&fs, Code::RngAmbient), 2, "{fs:?}");
    assert_eq!(count(&fs, Code::RngWallClock), 2, "{fs:?}");
    assert_eq!(count(&fs, Code::RngTimeSeed), 2, "{fs:?}");
    assert_eq!(count(&fs, Code::ObsClock), 2, "{fs:?}"); // 2x raw Instant
    assert_eq!(fs.len(), 8, "{fs:?}");
}

#[test]
fn bad_obs_fixture_exact_counts() {
    // non-hot, non-telemetry path: both TZ-OBS001 halves apply
    let fs = code_lint("rust/src/tensor/fixture_obs.rs",
                       include_str!("fixtures/bad_obs.rs"));
    assert_eq!(count(&fs, Code::ObsClock), 3, "{fs:?}");
    assert_eq!(fs.len(), 3, "{fs:?}");
}

#[test]
fn obs_clock_exemption_is_path_scoped() {
    // the same fixture inside the telemetry layer: the raw-clock half is
    // exempt there, but readouts steering kappa/wire stay flagged
    let fs = code_lint("rust/src/telemetry/fixture_obs.rs",
                       include_str!("fixtures/bad_obs.rs"));
    assert_eq!(count(&fs, Code::ObsClock), 2, "{fs:?}");
    assert_eq!(fs.len(), 2, "{fs:?}");
}

#[test]
fn bad_hash_order_fixture_exact_counts() {
    let fs = code_lint("rust/src/tensor/fixture_hash.rs",
                       include_str!("fixtures/bad_hash_order.rs"));
    assert_eq!(count(&fs, Code::DetHashOrder), 1, "{fs:?}");
    assert_eq!(count(&fs, Code::DetPartialSort), 1, "{fs:?}");
    assert_eq!(fs.len(), 2, "{fs:?}");
}

#[test]
fn hot_bad_panics_fixture_exact_counts() {
    let fs = code_lint("rust/src/runtime/fixture_panics.rs",
                       include_str!("fixtures/hot_bad_panics.rs"));
    assert_eq!(count(&fs, Code::PanicHotPath), 4, "{fs:?}");
    assert_eq!(count(&fs, Code::IndexHotPath), 1, "{fs:?}");
    assert_eq!(fs.len(), 5, "{fs:?}");
}

#[test]
fn hot_path_classification_gates_panic_rules() {
    // the same panicking fixture on a cold path yields zero findings
    let fs = code_lint("rust/src/tensor/fixture_panics.rs",
                       include_str!("fixtures/hot_bad_panics.rs"));
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn bad_io_fixture_exact_counts() {
    let fs = code_lint("rust/src/runtime/fixture_io.rs",
                       include_str!("fixtures/bad_io.rs"));
    assert_eq!(count(&fs, Code::IoRawWrite), 2, "{fs:?}");
    assert_eq!(fs.len(), 2, "{fs:?}");
}

#[test]
fn io_rule_is_hot_path_and_durable_scoped() {
    // same fixture on a cold path, and inside the durable module: clean
    for path in ["rust/src/tensor/fixture_io.rs",
                 "rust/src/runtime/durable.rs"] {
        let fs = code_lint(path, include_str!("fixtures/bad_io.rs"));
        assert_eq!(count(&fs, Code::IoRawWrite), 0, "{path}: {fs:?}");
    }
}

#[test]
fn bad_tune_fixture_exact_counts() {
    let fs = code_lint("rust/src/fleet/fixture_tune.rs",
                       include_str!("fixtures/bad_tune.rs"));
    assert_eq!(count(&fs, Code::TuneFormLiteral), 5, "{fs:?}");
    assert_eq!(fs.len(), 5, "{fs:?}");
}

#[test]
fn tune_exemption_is_path_scoped() {
    // the same fixture inside the vocabulary owners stays clean
    for path in ["rust/src/config/fixture_tune.rs",
                 "rust/src/runtime/tune.rs"] {
        let fs = code_lint(path, include_str!("fixtures/bad_tune.rs"));
        assert_eq!(count(&fs, Code::TuneFormLiteral), 0, "{path}: {fs:?}");
    }
}

#[test]
fn good_fixtures_are_clean() {
    let fs = code_lint("rust/src/rngx/fixture_good.rs",
                       include_str!("fixtures/good_rngx.rs"));
    assert!(fs.is_empty(), "{fs:?}");
    let fs = code_lint("rust/src/runtime/fixture_good.rs",
                       include_str!("fixtures/good_hot_guarded.rs"));
    assert!(fs.is_empty(), "{fs:?}");
}

fn artifact_lint(manifest_json: &str) -> Vec<Finding> {
    let files = vec![SourceFile::new(
        "rust/src/coordinator/optimizer/fixture_driver.rs".into(),
        include_str!("fixtures/driver_bind.rs"),
    )];
    let ms = vec![ManifestContracts::from_json("fixtures/manifest.json",
                                               manifest_json)
        .expect("fixture manifest parses")];
    let mut out = Vec::new();
    rules::artifacts::check(&files, &ms, &mut out);
    out
}

#[test]
fn driver_matches_committed_manifest() {
    let fs = artifact_lint(include_str!("fixtures/manifest_ok.json"));
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn seeded_manifest_slot_rename_is_caught() {
    // manifest regenerated with `scalar/seed` renamed to `scalar/seed_lo`:
    // the driver's bind_scalar_u32("seed", ..) must be flagged
    let fs = artifact_lint(include_str!("fixtures/manifest_renamed.json"));
    let mismatches: Vec<_> =
        fs.iter().filter(|f| f.code == Code::ArtSlotMismatch).collect();
    assert_eq!(mismatches.len(), 1, "{fs:?}");
    assert!(mismatches[0].message.contains("seed"), "{fs:?}");
    assert!(mismatches[0].file.contains("fixture_driver.rs"));
}
