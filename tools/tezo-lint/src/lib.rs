//! tezo-lint: static enforcement of the workspace invariants that the
//! type system cannot see — seed determinism, panic-free hot paths, and
//! the driver/manifest artifact contract. See `docs/invariants.md` for
//! the rule catalogue and `lint/allowlist.txt` for the (empty) baseline.
//!
//! Zero dependencies by design: this crate must build and run where the
//! PJRT toolchain does not, so CI can gate on invariants before the heavy
//! `tezo` build.

pub mod allowlist;
pub mod findings;
pub mod lexer;
pub mod manifestx;
pub mod rules;
pub mod source;

use findings::{Code, Finding};
use manifestx::ManifestContracts;
use source::SourceFile;
use std::path::{Path, PathBuf};

/// Directories (repo-relative) scanned for Rust sources. `tools/` is
/// excluded: the linter's own fixtures intentionally violate every rule.
const SCAN_ROOTS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "examples"];

const MANIFEST_GLOB_DIR: &str = "artifacts";

pub struct Config {
    /// repository root (defaults to cwd)
    pub root: PathBuf,
    /// allowlist file, repo-relative
    pub allowlist: String,
    /// report file, repo-relative (written unless empty)
    pub report: String,
}

impl Config {
    pub fn new(root: PathBuf) -> Config {
        Config {
            root,
            allowlist: "lint/allowlist.txt".into(),
            report: "out/lint_report.json".into(),
        }
    }
}

/// Load every `.rs` file under the scan roots, sorted for deterministic
/// finding order. Unreadable files are reported, not panicked on.
pub fn load_sources(cfg: &Config) -> Result<Vec<SourceFile>, String> {
    let mut paths = Vec::new();
    for rootdir in SCAN_ROOTS {
        let dir = cfg.root.join(rootdir);
        if dir.is_dir() {
            walk_rs(&dir, &mut paths)?;
        }
    }
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for p in paths {
        let src = std::fs::read_to_string(&p)
            .map_err(|e| format!("read {}: {e}", p.display()))?;
        out.push(SourceFile::new(rel(&cfg.root, &p), &src));
    }
    Ok(out)
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("walk {}: {e}", dir.display()))?;
    for entry in rd {
        let entry = entry.map_err(|e| format!("walk {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with('.') || name == "target" {
            continue;
        }
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Load every `artifacts/*/manifest.json`, sorted by path.
pub fn load_manifests(cfg: &Config) -> Result<Vec<ManifestContracts>, String> {
    let dir = cfg.root.join(MANIFEST_GLOB_DIR);
    let mut paths = Vec::new();
    if dir.is_dir() {
        let rd = std::fs::read_dir(&dir).map_err(|e| format!("walk {}: {e}", dir.display()))?;
        for entry in rd {
            let entry = entry.map_err(|e| format!("walk {}: {e}", dir.display()))?;
            let m = entry.path().join("manifest.json");
            if m.is_file() {
                paths.push(m);
            }
        }
    }
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for p in paths {
        let src = std::fs::read_to_string(&p)
            .map_err(|e| format!("read {}: {e}", p.display()))?;
        out.push(
            ManifestContracts::from_json(&rel(&cfg.root, &p), &src)
                .map_err(|e| format!("parse {}: {e}", p.display()))?,
        );
    }
    Ok(out)
}

fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Code rules (RNG/time, determinism, panic-free hot paths, telemetry
/// clock discipline) over the given sources.
pub fn run_code_lint(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        rules::rng_time::check(f, &mut out);
        rules::determinism::check(f, &mut out);
        rules::panics::check(f, &mut out);
        rules::obs::check(f, &mut out);
        rules::tune::check(f, &mut out);
        rules::io::check(f, &mut out);
    }
    out
}

/// Artifact-contract rules over sources + manifests.
pub fn run_artifact_lint(files: &[SourceFile], manifests: &[ManifestContracts])
                         -> Vec<Finding> {
    let mut out = Vec::new();
    rules::artifacts::check(files, manifests, &mut out);
    out
}

/// Apply the allowlist baseline (missing file = empty baseline), then
/// sort findings by (file, line, code) for stable reports.
pub fn finalize(cfg: &Config, mut findings: Vec<Finding>) -> Vec<Finding> {
    let path = cfg.root.join(&cfg.allowlist);
    if let Ok(text) = std::fs::read_to_string(&path) {
        let entries = allowlist::parse(&text);
        allowlist::apply(&entries, &cfg.allowlist, &mut findings);
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.code).cmp(&(b.file.as_str(), b.line, b.code))
    });
    findings
}

/// True if the run should fail: any non-allowlisted finding. TZ-ART003 is
/// advisory (warn) and never fails the run.
pub fn has_errors(findings: &[Finding]) -> bool {
    findings
        .iter()
        .any(|f| !f.allowlisted && f.code != Code::ArtUnreferenced)
}
