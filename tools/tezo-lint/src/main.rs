//! CLI for tezo-lint.
//!
//! ```text
//! tezo-lint [MODE] [--root DIR] [--deny-all] [--report PATH] [--allowlist PATH]
//!
//! MODE: code      RNG/time, determinism, panic-free hot paths
//!       artifact  driver literals vs artifacts/*/manifest.json
//!       all       both (default)
//! ```
//!
//! Exit codes: 0 clean (or warnings only), 1 findings, 2 usage/IO error.
//! A JSON report is always written (default `out/lint_report.json`).
//!
//! Cargo aliases (.cargo/config.toml): `cargo tezo-lint` runs `all`
//! with `--deny-all`; `cargo artifact-lint` runs the artifact mode.

use std::path::PathBuf;
use std::process::ExitCode;
use tezo_lint::{findings, finalize, has_errors, load_manifests, load_sources, Config};

fn main() -> ExitCode {
    match run() {
        Ok(clean) => {
            if clean {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("tezo-lint: error: {e}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<bool, String> {
    let mut mode = "all".to_string();
    let mut cfg = Config::new(PathBuf::from("."));
    let mut deny_all = false;

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "code" | "artifact" | "all" => mode = a,
            "--deny-all" => deny_all = true,
            "--root" => cfg.root = PathBuf::from(take(&mut args, "--root")?),
            "--report" => cfg.report = take(&mut args, "--report")?,
            "--allowlist" => cfg.allowlist = take(&mut args, "--allowlist")?,
            "--help" | "-h" => {
                println!("usage: tezo-lint [code|artifact|all] [--root DIR] \
                          [--deny-all] [--report PATH] [--allowlist PATH]");
                return Ok(true);
            }
            other => return Err(format!("unknown argument `{other}` (--help)")),
        }
    }

    let files = load_sources(&cfg)?;
    if files.is_empty() {
        return Err(format!("no Rust sources under {}", cfg.root.display()));
    }

    let mut found = Vec::new();
    if mode == "code" || mode == "all" {
        found.extend(tezo_lint::run_code_lint(&files));
    }
    if mode == "artifact" || mode == "all" {
        let manifests = load_manifests(&cfg)?;
        if manifests.is_empty() {
            return Err("no artifacts/*/manifest.json found".into());
        }
        found.extend(tezo_lint::run_artifact_lint(&files, &manifests));
    }
    let found = finalize(&cfg, found);

    print!("{}", findings::render_text(&found));
    let active = found.iter().filter(|f| !f.allowlisted).count();
    eprintln!(
        "tezo-lint[{mode}]: {} file(s), {} finding(s) ({} allowlisted)",
        files.len(),
        found.len(),
        found.len() - active,
    );

    if !cfg.report.is_empty() {
        let report_path = cfg.root.join(&cfg.report);
        if let Some(dir) = report_path.parent() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
        }
        std::fs::write(&report_path, findings::render_json(&found, &mode, deny_all))
            .map_err(|e| format!("write {}: {e}", report_path.display()))?;
    }

    // without --deny-all, advisory severities don't fail the run; with it,
    // anything non-allowlisted does (TZ-ART003 stays advisory either way)
    let fail = if deny_all {
        has_errors(&found)
    } else {
        found.iter().any(|f| {
            !f.allowlisted
                && !matches!(f.code,
                             findings::Code::ArtUnreferenced
                             | findings::Code::IndexHotPath)
        })
    };
    Ok(!fail)
}

fn take(args: &mut impl Iterator<Item = String>, flag: &str) -> Result<String, String> {
    args.next().ok_or_else(|| format!("{flag} requires a value"))
}
