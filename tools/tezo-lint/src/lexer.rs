//! A minimal Rust lexer: identifiers, literals, punctuation, with line
//! numbers, comments and whitespace stripped.
//!
//! This is NOT a full Rust grammar — it is exactly the token stream the
//! rule passes need: idents and string literals are preserved verbatim,
//! char literals are distinguished from lifetimes, raw/byte strings are
//! consumed as single tokens, and nested block comments are skipped. Every
//! rule in `rules/` works on this stream plus balanced-delimiter scanning.

/// One lexed token.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub kind: Kind,
    /// identifier text, string-literal *contents* (unescaped only for
    /// simple escapes), or the punctuation character as a 1-char string
    pub text: String,
    /// 1-based source line
    pub line: u32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// identifier or keyword (`fn`, `for`, `HashMap`, ...)
    Ident,
    /// `'a` in `&'a str` (distinguished from char literals)
    Lifetime,
    /// string literal (including raw/byte forms); `text` is the contents
    Str,
    /// char or byte literal; `text` is the raw source slice
    Char,
    /// numeric literal
    Num,
    /// single punctuation character (`{`, `}`, `.`, `!`, `=`, ...)
    Punct,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.len() == 1
            && self.text.as_bytes()[0] as char == c
    }
}

/// Lex `src` into tokens. Unterminated constructs are tolerated (the rest
/// of the file becomes one token) — a linter must never panic on its input.
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    out: Vec<Token>,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Vec<Token> {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b' ' | b'\t' | b'\r' => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'r' | b'b' if self.raw_string_ahead() => self.raw_string(),
                b'b' if self.peek(1) == Some(b'"') => {
                    self.i += 1;
                    self.string();
                }
                b'b' if self.peek(1) == Some(b'\'') => {
                    self.i += 1;
                    self.char_lit();
                }
                b'"' => self.string(),
                b'\'' => self.quote(),
                c if c == b'_' || c.is_ascii_alphabetic() => self.ident(),
                c if c.is_ascii_digit() => self.number(),
                _ => {
                    self.push(Kind::Punct, (c as char).to_string());
                    self.i += 1;
                }
            }
        }
        self.out
    }

    fn peek(&self, off: usize) -> Option<u8> {
        self.b.get(self.i + off).copied()
    }

    fn push(&mut self, kind: Kind, text: String) {
        self.out.push(Token { kind, text, line: self.line });
    }

    fn line_comment(&mut self) {
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
    }

    fn block_comment(&mut self) {
        self.i += 2;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            match self.b[self.i] {
                b'\n' => self.line += 1,
                b'/' if self.peek(1) == Some(b'*') => {
                    depth += 1;
                    self.i += 1;
                }
                b'*' if self.peek(1) == Some(b'/') => {
                    depth -= 1;
                    self.i += 1;
                }
                _ => {}
            }
            self.i += 1;
        }
    }

    /// `r"..."`, `r#"..."#`, `br"..."` ahead at the cursor?
    fn raw_string_ahead(&self) -> bool {
        let mut j = self.i;
        if self.b[j] == b'b' {
            j += 1;
        }
        if self.b.get(j) != Some(&b'r') {
            return false;
        }
        j += 1;
        while self.b.get(j) == Some(&b'#') {
            j += 1;
        }
        self.b.get(j) == Some(&b'"')
    }

    fn raw_string(&mut self) {
        if self.b[self.i] == b'b' {
            self.i += 1;
        }
        self.i += 1; // 'r'
        let mut hashes = 0usize;
        while self.peek(0) == Some(b'#') {
            hashes += 1;
            self.i += 1;
        }
        self.i += 1; // opening quote
        let start = self.i;
        loop {
            match self.peek(0) {
                None => break,
                Some(b'\n') => {
                    self.line += 1;
                    self.i += 1;
                }
                Some(b'"') => {
                    // need `hashes` trailing '#'
                    let mut ok = true;
                    for k in 0..hashes {
                        if self.peek(1 + k) != Some(b'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        break;
                    }
                    self.i += 1;
                }
                Some(_) => self.i += 1,
            }
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i.min(self.b.len())])
            .into_owned();
        self.push(Kind::Str, text);
        self.i += 1 + hashes; // closing quote + hashes (saturates at EOF)
    }

    fn string(&mut self) {
        self.i += 1; // opening quote
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            match c {
                b'"' => break,
                b'\\' => {
                    // keep escapes simple: unescape the common ones, pass
                    // everything else through verbatim
                    match self.peek(1) {
                        Some(b'n') => text.push('\n'),
                        Some(b't') => text.push('\t'),
                        Some(b'r') => text.push('\r'),
                        Some(b'"') => text.push('"'),
                        Some(b'\\') => text.push('\\'),
                        Some(other) => {
                            text.push('\\');
                            text.push(other as char);
                        }
                        None => break,
                    }
                    self.i += 2;
                }
                b'\n' => {
                    self.line += 1;
                    text.push('\n');
                    self.i += 1;
                }
                _ => {
                    text.push(c as char);
                    self.i += 1;
                }
            }
        }
        self.i += 1; // closing quote
        self.push(Kind::Str, text);
    }

    fn char_lit(&mut self) {
        // at the opening quote of a char/byte literal
        let start = self.i;
        self.i += 1;
        if self.peek(0) == Some(b'\\') {
            self.i += 2;
        } else {
            self.i += 1;
        }
        // multi-byte UTF-8 chars: advance to the closing quote
        while self.i < self.b.len() && self.b[self.i] != b'\'' {
            self.i += 1;
        }
        self.i += 1;
        let text = String::from_utf8_lossy(&self.b[start..self.i.min(self.b.len())])
            .into_owned();
        self.push(Kind::Char, text);
    }

    /// `'` is either a lifetime (`'a`, `'static`) or a char literal.
    fn quote(&mut self) {
        // lifetime: 'ident NOT followed by a closing quote
        let mut j = self.i + 1;
        while j < self.b.len()
            && (self.b[j] == b'_' || self.b[j].is_ascii_alphanumeric())
        {
            j += 1;
        }
        let is_lifetime = j > self.i + 1 && self.b.get(j) != Some(&b'\'');
        if is_lifetime {
            let text = String::from_utf8_lossy(&self.b[self.i + 1..j]).into_owned();
            self.push(Kind::Lifetime, text);
            self.i = j;
        } else {
            self.char_lit();
        }
    }

    fn ident(&mut self) {
        let start = self.i;
        while self.i < self.b.len()
            && (self.b[self.i] == b'_' || self.b[self.i].is_ascii_alphanumeric())
        {
            self.i += 1;
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.push(Kind::Ident, text);
    }

    fn number(&mut self) {
        let start = self.i;
        while self.i < self.b.len()
            && (self.b[self.i] == b'_'
                || self.b[self.i] == b'.'
                || self.b[self.i].is_ascii_alphanumeric())
        {
            // `0..10` range punctuation must not be eaten by the number
            if self.b[self.i] == b'.' && self.peek(1) == Some(b'.') {
                break;
            }
            // `.method()` on a literal: stop before an alphabetic method name
            if self.b[self.i] == b'.'
                && self.peek(1).is_some_and(|c| c.is_ascii_alphabetic() || c == b'_')
                && !self.b[start..self.i].contains(&b'x')
            {
                break;
            }
            self.i += 1;
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.push(Kind::Num, text);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_and_lines() {
        let ts = lex("fn main() {\n  let x = 1;\n}");
        assert!(ts[0].is_ident("fn"));
        assert!(ts[1].is_ident("main"));
        assert!(ts[2].is_punct('('));
        let x = ts.iter().find(|t| t.is_ident("x")).unwrap();
        assert_eq!(x.line, 2);
    }

    #[test]
    fn strings_chars_lifetimes() {
        let ts = kinds(r#"let s = "a\nb"; let c = 'x'; fn f<'a>(v: &'a str) {}"#);
        assert!(ts.contains(&(Kind::Str, "a\nb".to_string())));
        assert!(ts.contains(&(Kind::Char, "'x'".to_string())));
        assert!(ts.contains(&(Kind::Lifetime, "a".to_string())));
    }

    #[test]
    fn raw_strings_and_comments() {
        let ts = kinds("// skip\n/* also /* nested */ skip */ let r = r#\"raw \"q\" text\"#;");
        assert!(ts.contains(&(Kind::Str, "raw \"q\" text".to_string())));
        assert!(!ts.iter().any(|(_, s)| s.contains("skip")));
    }

    #[test]
    fn numbers_do_not_eat_ranges_or_methods() {
        let ts = kinds("for i in 0..10 { x1.max(2.5); 1.0f64; }");
        assert!(ts.contains(&(Kind::Num, "0".to_string())));
        assert!(ts.contains(&(Kind::Num, "10".to_string())));
        assert!(ts.contains(&(Kind::Num, "2.5".to_string())));
        assert!(ts.contains(&(Kind::Num, "1.0f64".to_string())));
        assert!(ts.contains(&(Kind::Ident, "max".to_string())));
    }

    #[test]
    fn never_panics_on_garbage() {
        for bad in ["\"unterminated", "r#\"open", "'", "/* open", "b'"] {
            let _ = lex(bad);
        }
    }
}
