//! The allowlist baseline: per-entry-justified suppressions.
//!
//! Format (`lint/allowlist.txt`), one entry per line:
//!
//! ```text
//! TZ-PANIC002  rust/src/runtime/plan.rs  slot positions proven in-bounds by construction
//! ```
//!
//! i.e. `CODE  PATH-SUBSTRING  JUSTIFICATION`, whitespace-separated with
//! the justification running to end of line. `#` starts a comment. The
//! policy (docs/invariants.md): the file must be empty, or every entry
//! must carry a justification AND match at least one current finding —
//! entries without a justification and entries that no longer match
//! anything are themselves findings (`TZ-ALLOW001`), so the baseline can
//! only shrink honestly.

use crate::findings::{Code, Finding};

#[derive(Clone, Debug)]
pub struct Entry {
    pub code: String,
    pub path_substring: String,
    pub justification: String,
    pub line: u32,
}

/// Parse the allowlist text. Never fails: malformed lines become
/// zero-justification entries, which the stale-entry check then flags.
pub fn parse(text: &str) -> Vec<Entry> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(3, char::is_whitespace);
        let code = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").trim().to_string();
        let justification = parts.next().unwrap_or("").trim().to_string();
        out.push(Entry {
            code,
            path_substring: path,
            justification,
            line: (i + 1) as u32,
        });
    }
    out
}

/// Apply `entries` to `findings`: matching findings are marked
/// `allowlisted`; unjustified or non-matching entries append
/// `TZ-ALLOW001` findings against the allowlist file itself.
pub fn apply(entries: &[Entry], allowlist_path: &str, findings: &mut Vec<Finding>) {
    for e in entries {
        let mut matched = false;
        for f in findings.iter_mut() {
            if f.code.as_str() == e.code && f.file.contains(&e.path_substring) {
                f.allowlisted = true;
                matched = true;
            }
        }
        if e.justification.split_whitespace().count() < 3 {
            findings.push(Finding::new(
                Code::AllowlistStale,
                allowlist_path,
                e.line,
                format!("entry `{} {}` lacks a justification (≥3 words required)",
                        e.code, e.path_substring),
            ));
        } else if !matched {
            findings.push(Finding::new(
                Code::AllowlistStale,
                allowlist_path,
                e.line,
                format!("stale entry: no current {} finding matches path `{}` — delete it",
                        e.code, e.path_substring),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_skips_comments_and_splits_fields() {
        let es = parse("# header\nTZ-PANIC001 src/a.rs proven safe by arity check\n\n");
        assert_eq!(es.len(), 1);
        assert_eq!(es[0].code, "TZ-PANIC001");
        assert_eq!(es[0].path_substring, "src/a.rs");
        assert!(es[0].justification.starts_with("proven"));
    }

    #[test]
    fn apply_marks_matches_and_flags_stale() {
        let mut fs = vec![Finding::new(Code::PanicHotPath, "rust/src/a.rs", 5,
                                       "unwrap".into())];
        let es = parse(
            "TZ-PANIC001 src/a.rs checked by caller before dispatch\n\
             TZ-PANIC001 src/missing.rs justified but matches nothing\n\
             TZ-DET001 src/a.rs bad",
        );
        apply(&es, "lint/allowlist.txt", &mut fs);
        assert!(fs[0].allowlisted);
        let stale: Vec<_> =
            fs.iter().filter(|f| f.code == Code::AllowlistStale).collect();
        assert_eq!(stale.len(), 2, "one stale path + one missing justification");
    }
}
