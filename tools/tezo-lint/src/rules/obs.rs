//! Telemetry / clock discipline (TZ-OBS001).
//!
//! PR 8 confines every wall-clock read to the telemetry layer: the rest
//! of the workspace measures durations through `telemetry::Stopwatch`
//! and takes timestamps from the tracer's `Clock`, which is what lets a
//! `TestClock` make whole-run traces byte-deterministic. Two halves:
//!
//! * a raw monotonic clock type (`Instant`) outside `telemetry/`,
//!   `benchkit/`, `rngx/`, or the benches is denied — use `Stopwatch`
//!   or the tracer's clock so the read stays swappable. (`SystemTime` /
//!   `UNIX_EPOCH` stay TZ-RNG002's business.)
//! * a telemetry readout (`now_ns`, `elapsed_ns`, quantiles, ...) in the
//!   same statement as a kappa/wire/perturb sink is flagged: the tracer
//!   observes the run and must never steer it. The seed direction is
//!   already TZ-RNG003; this closes the kappa and wire directions.

use crate::findings::{Code, Finding};
use crate::rules::statement_around;
use crate::source::SourceFile;

/// Raw clock types the telemetry layer wraps.
const CLOCK_TYPES: &[&str] = &["Instant"];

/// Read-direction telemetry identifiers — values coming *out* of the
/// layer. Write-direction calls (`counter`, `mark`, `record_ns`,
/// `span_from`, `secs_to_ns`) are deliberately absent: feeding kappa or
/// loss *into* the tracer is the intended observational flow.
const TELEM_READS: &[&str] = &[
    "now_ns", "elapsed", "elapsed_ns", "elapsed_secs", "dur_ns", "ts_ns",
    "quantile_ns", "p50_ns", "p95_ns", "p99_ns", "mean_ns", "sum_ns",
    "min_ns", "max_ns",
];

/// Identifiers marking state a telemetry readout must never reach.
const OBS_SINKS: &[&str] = &["kappa", "wire", "frame", "encode", "perturb"];

/// Modules allowed to touch the raw clock: the telemetry layer itself,
/// the bench harnesses (which report real wall time by definition), and
/// rngx (whose lint tests exercise clock tokens).
fn clock_ok(path: &str) -> bool {
    path.contains("/telemetry/") || path.contains("/benchkit/")
        || path.contains("/rngx/") || path.contains("/benches/")
}

pub fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    let raw_clock_ok = clock_ok(&file.path);

    for (i, t) in file.tokens.iter().enumerate() {
        if file.masked[i] || t.kind != crate::lexer::Kind::Ident {
            continue;
        }
        let name = t.text.as_str();

        if !raw_clock_ok && CLOCK_TYPES.contains(&name) {
            out.push(Finding::new(
                Code::ObsClock,
                &file.path,
                t.line,
                format!("raw clock `{name}` outside the telemetry layer — \
                         use telemetry::Stopwatch or the tracer's Clock"),
            ));
            continue;
        }

        if TELEM_READS.contains(&name) {
            let (lo, hi) = statement_around(&file.tokens, i);
            let sink = file.tokens[lo..=hi].iter().find(|s| {
                s.kind == crate::lexer::Kind::Ident
                    && OBS_SINKS.iter().any(|k| {
                        let id = s.text.to_ascii_lowercase();
                        id == *k || id.starts_with(&format!("{k}_"))
                            || id.ends_with(&format!("_{k}"))
                    })
            });
            if let Some(s) = sink {
                out.push(Finding::new(
                    Code::ObsClock,
                    &file.path,
                    t.line,
                    format!("telemetry readout `{name}` flows into `{}` — \
                             the tracer is observational only", s.text),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn flags_raw_instant_outside_telemetry() {
        let fs = findings("rust/src/fleet/worker.rs",
                          "fn f() { let t0 = Instant::now(); }");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].code, Code::ObsClock);
    }

    #[test]
    fn telemetry_benchkit_and_benches_are_exempt() {
        for path in ["rust/src/telemetry/clock.rs", "rust/src/benchkit/mod.rs",
                     "rust/benches/bench_walltime.rs"] {
            assert!(findings(path, "fn f() { let t0 = Instant::now(); }")
                        .is_empty(),
                    "{path} should be exempt");
        }
    }

    #[test]
    fn flags_readout_flowing_into_kappa_and_wire() {
        let fs = findings(
            "rust/src/coordinator/step.rs",
            "fn f() { let kappa = tel.now_ns() as f64; \
             let frame = encode(h.p99_ns()); }",
        );
        assert_eq!(fs.len(), 2);
        assert!(fs.iter().all(|f| f.code == Code::ObsClock));
    }

    #[test]
    fn observational_counters_are_fine() {
        // write-direction: kappa flowing INTO the tracer is the point
        let fs = findings(
            "rust/src/fleet/coordinator.rs",
            "fn f() { tel.counter(\"round\", \"kappa\", kappa, step); \
             tel.span_dur(\"round\", \"forward\", secs_to_ns(t), w, s); }",
        );
        assert!(fs.is_empty());
    }

    #[test]
    fn pure_timing_statements_are_fine() {
        let fs = findings(
            "rust/src/fleet/tcp.rs",
            "fn f() { if start.elapsed() > STALL_BUDGET { return; } \
             let dt = sw.elapsed_secs(); record(dt); }",
        );
        assert!(fs.is_empty());
    }

    #[test]
    fn test_code_is_masked() {
        let fs = findings("rust/src/fleet/worker.rs",
                          "#[test]\nfn t() { let t0 = Instant::now(); }");
        assert!(fs.is_empty());
    }
}
