//! Ordering determinism (TZ-DET001..002).
//!
//! Floating-point reduction is not associative, and the fleet protocol
//! must emit byte-identical streams across runs, so iteration order is
//! part of correctness here — the paper's seed-sync scheme only works if
//! every worker reduces in the same order.
//!
//! * TZ-DET001 — a `for` loop over a `HashMap`/`HashSet` (hash order!)
//!   whose body accumulates (`+=`, `push`, `extend`, ...) or emits
//!   (`send`, `write`, ...). Iterate a `Vec`/`BTreeMap` or sort first.
//! * TZ-DET002 — float ordering via `partial_cmp(..).unwrap()` inside a
//!   sort/min/max statement: panics on NaN and under-defines the order;
//!   use `f32::total_cmp`/`f64::total_cmp`.

use crate::findings::{Code, Finding};
use crate::lexer::Kind;
use crate::rules::statement_around;
use crate::source::{matching_close, SourceFile};

const HASH_TYPES: &[&str] = &["HashMap", "HashSet"];

/// Identifiers whose call in a loop body means "order-sensitive effect".
const ACCUMULATORS: &[&str] = &[
    "push", "push_str", "extend", "send", "write", "writeln", "write_all",
    "emit", "append",
];

const ORDER_FNS: &[&str] = &[
    "sort_by", "sort_unstable_by", "sort_by_key", "min_by", "max_by",
    "binary_search_by",
];

pub fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    let hash_vars = hash_typed_vars(file);
    check_hash_order(file, &hash_vars, out);
    check_partial_sort(file, out);
}

/// Names bound to a `HashMap`/`HashSet` in this file: `let [mut] NAME =
/// HashMap::…` / `let [mut] NAME: HashMap<…>` / `NAME: HashMap<…>` fields.
fn hash_typed_vars(file: &SourceFile) -> Vec<String> {
    let mut vars = Vec::new();
    for (i, t) in file.tokens.iter().enumerate() {
        if !(t.kind == Kind::Ident && HASH_TYPES.contains(&t.text.as_str())) {
            continue;
        }
        // scan back past path segments (`std :: collections ::`) and the
        // `=`/`:` binder to the bound identifier
        let mut j = i;
        while j >= 2 && file.tokens[j - 1].is_punct(':') && file.tokens[j - 2].is_punct(':') {
            j -= 2;
            if j > 0 && file.tokens[j - 1].kind == Kind::Ident {
                j -= 1;
            }
        }
        // skip reference/mutability qualifiers: `m: &mut HashMap<..>`
        while j > 0
            && (file.tokens[j - 1].is_punct('&')
                || file.tokens[j - 1].is_ident("mut")
                || file.tokens[j - 1].kind == Kind::Lifetime)
        {
            j -= 1;
        }
        if j == 0 {
            continue;
        }
        let binder = &file.tokens[j - 1];
        if (binder.is_punct('=') || binder.is_punct(':')) && j >= 2 {
            let name = &file.tokens[j - 2];
            if name.kind == Kind::Ident {
                vars.push(name.text.clone());
            }
        }
    }
    vars.sort_unstable();
    vars.dedup();
    vars
}

fn check_hash_order(file: &SourceFile, hash_vars: &[String], out: &mut Vec<Finding>) {
    let ts = &file.tokens;
    for (i, t) in ts.iter().enumerate() {
        if file.masked[i] || !t.is_ident("for") {
            continue;
        }
        // header: `for PAT in EXPR {` — find `in`, then the body `{` at
        // bracket depth 0
        let Some(in_pos) = (i..ts.len().min(i + 40)).find(|&k| ts[k].is_ident("in"))
        else {
            continue;
        };
        let mut k = in_pos + 1;
        let mut body_open = None;
        while k < ts.len() {
            if ts[k].is_punct('(') || ts[k].is_punct('[') {
                k = matching_close(ts, k) + 1;
                continue;
            }
            if ts[k].is_punct('{') {
                body_open = Some(k);
                break;
            }
            if ts[k].is_punct(';') {
                break;
            }
            k += 1;
        }
        let Some(open) = body_open else { continue };

        let expr = &ts[in_pos + 1..open];
        let over_hash = expr.iter().any(|e| {
            e.kind == Kind::Ident
                && (HASH_TYPES.contains(&e.text.as_str())
                    || hash_vars.iter().any(|v| v == &e.text))
        });
        if !over_hash {
            continue;
        }
        // an explicit sort in the iterated expression restores determinism
        if expr.iter().any(|e| e.kind == Kind::Ident && e.text.starts_with("sort")) {
            continue;
        }

        let close = matching_close(ts, open);
        let body = &ts[open..=close];
        let accumulates = body.windows(2).any(|w| w[0].is_punct('+') && w[1].is_punct('='))
            || body.iter().any(|b| {
                b.kind == Kind::Ident && ACCUMULATORS.contains(&b.text.as_str())
            });
        if accumulates {
            out.push(Finding::new(
                Code::DetHashOrder,
                &file.path,
                t.line,
                "hash-ordered iteration feeds accumulation/emission — order \
                 is nondeterministic; use a Vec/BTreeMap or sort keys first"
                    .into(),
            ));
        }
    }
}

fn check_partial_sort(file: &SourceFile, out: &mut Vec<Finding>) {
    for (i, t) in file.tokens.iter().enumerate() {
        if file.masked[i] || !t.is_ident("partial_cmp") {
            continue;
        }
        let (lo, hi) = statement_around(&file.tokens, i);
        let stmt = &file.tokens[lo..=hi];
        let in_order_fn = stmt
            .iter()
            .any(|s| s.kind == Kind::Ident && ORDER_FNS.contains(&s.text.as_str()));
        let unwraps = file.tokens[i..=hi].iter().any(|s| s.is_ident("unwrap"));
        if in_order_fn && unwraps {
            out.push(Finding::new(
                Code::DetPartialSort,
                &file.path,
                t.line,
                "float ordering via partial_cmp().unwrap() — panics on NaN \
                 and under-defines the order; use total_cmp"
                    .into(),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(src: &str) -> Vec<Finding> {
        let f = SourceFile::new("rust/src/x.rs".into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn flags_hash_iteration_with_accumulation() {
        let fs = findings(
            "fn f() { let mut m: HashMap<u32, f32> = HashMap::new(); \
             let mut total = 0.0; \
             for (_, v) in m.iter() { total += v; } }",
        );
        assert_eq!(fs.iter().filter(|f| f.code == Code::DetHashOrder).count(), 1);
    }

    #[test]
    fn vec_iteration_is_fine() {
        let fs = findings(
            "fn f(v: &[f32]) { let mut t = 0.0; for x in v { t += x; } }",
        );
        assert!(fs.is_empty());
    }

    #[test]
    fn lookup_only_hash_use_is_fine() {
        let fs = findings(
            "fn f() { let mut m = std::collections::HashMap::new(); \
             m.insert(1, 2); let x = m.get(&1); }",
        );
        assert!(fs.is_empty());
    }

    #[test]
    fn sorted_keys_are_fine() {
        let fs = findings(
            "fn f(m: &HashMap<u32, f32>) { let mut ks: Vec<_> = m.keys().collect(); \
             ks.sort(); let mut t = 0.0; \
             for k in ks.iter() { t += m[k]; } }",
        );
        assert!(fs.iter().all(|f| f.code != Code::DetHashOrder));
    }

    #[test]
    fn flags_partial_cmp_sort() {
        let fs = findings(
            "fn f(v: &mut Vec<f32>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }",
        );
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].code, Code::DetPartialSort);
    }

    #[test]
    fn total_cmp_sort_is_fine() {
        let fs = findings("fn f(v: &mut Vec<f32>) { v.sort_by(f32::total_cmp); }");
        assert!(fs.is_empty());
    }
}
