//! Panic-free hot paths (TZ-PANIC001..002).
//!
//! The runtime, step engine, optimizer drivers, fleet, and the jsonx
//! substrate sit on the training hot path: a panic there aborts a
//! multi-hour run (or, in the fleet, poisons a worker and desyncs the
//! seed schedule). These modules must surface failures as `Result` and
//! let the coordinator decide.
//!
//! * TZ-PANIC001 — `.unwrap()` / `.expect(..)` / `panic!` / `unreachable!`
//!   / `todo!` / `unimplemented!` in a hot-path module (test code exempt).
//! * TZ-PANIC002 — identifier indexing (`xs[i]`, `&b[a..c]`) in a
//!   hot-path function with no visible bounds discipline — no
//!   `len`/`get`/`enumerate`/`zip`/`assert`-family identifier anywhere in
//!   the enclosing function. Indexing under a checked invariant is fine;
//!   the check just has to be in view.

use crate::findings::{Code, Finding};
use crate::lexer::Kind;
use crate::rules::is_method_call;
use crate::source::SourceFile;

/// Method calls that panic on Err/None.
const PANIC_METHODS: &[&str] = &["unwrap", "expect"];

/// Diverging macros (identifier must be followed by `!`).
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Seeing any of these identifiers in the enclosing function counts as
/// bounds discipline for TZ-PANIC002.
const GUARD_IDENTS: &[&str] = &[
    "ensure", "assert", "assert_eq", "assert_ne", "debug_assert",
    "debug_assert_eq", "debug_assert_ne", "len", "get", "get_mut",
    "enumerate", "zip",
];

/// Is `path` on the training hot path? (repo-relative, `/`-separated)
pub fn is_hot_path(path: &str) -> bool {
    const HOT: &[&str] = &[
        "rust/src/runtime/",
        "rust/src/coordinator/step.rs",
        "rust/src/coordinator/optimizer/",
        "rust/src/fleet/",
        "rust/src/jsonx/",
    ];
    HOT.iter().any(|h| path.contains(h))
}

pub fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if !is_hot_path(&file.path) {
        return;
    }
    let ts = &file.tokens;
    for (i, t) in ts.iter().enumerate() {
        if file.masked[i] || t.kind != Kind::Ident {
            // unguarded indexing: `ident [` outside test code
            if !file.masked[i]
                && t.is_punct('[')
                && i > 0
                && ts[i - 1].kind == Kind::Ident
                && file.enclosing_fn(i).is_some()
                && !file.fn_contains_ident(i, GUARD_IDENTS)
            {
                out.push(Finding::new(
                    Code::IndexHotPath,
                    &file.path,
                    t.line,
                    format!("unguarded indexing of `{}` in a hot-path fn with \
                             no visible bounds check — use .get() or add the \
                             invariant as a debug_assert", ts[i - 1].text),
                ));
            }
            continue;
        }
        let name = t.text.as_str();
        if PANIC_METHODS.contains(&name) && is_method_call(ts, i) {
            out.push(Finding::new(
                Code::PanicHotPath,
                &file.path,
                t.line,
                format!(".{name}() on the hot path — return a typed error \
                         (anyhow::Result + context) instead"),
            ));
        } else if PANIC_MACROS.contains(&name)
            && ts.get(i + 1).is_some_and(|n| n.is_punct('!'))
        {
            out.push(Finding::new(
                Code::PanicHotPath,
                &file.path,
                t.line,
                format!("{name}! on the hot path — surface the failure as an \
                         error; the coordinator decides whether to abort"),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn flags_unwrap_and_macros_in_hot_path() {
        let fs = findings(
            "rust/src/runtime/plan.rs",
            "fn f() { x.unwrap(); y.expect(\"m\"); unreachable!(\"slot\"); }",
        );
        assert_eq!(fs.iter().filter(|f| f.code == Code::PanicHotPath).count(), 3);
    }

    #[test]
    fn cold_paths_are_not_checked() {
        let fs = findings("rust/src/main.rs", "fn f() { x.unwrap(); v[0]; }");
        assert!(fs.is_empty());
    }

    #[test]
    fn std_panic_module_is_not_a_macro() {
        let fs = findings("rust/src/fleet/worker.rs",
                          "fn f() { std::panic::catch_unwind(|| 1); }");
        assert!(fs.is_empty());
    }

    #[test]
    fn unguarded_indexing_flagged_guarded_ok() {
        let bad = findings("rust/src/fleet/protocol.rs",
                           "fn f(v: &[f32], i: usize) -> f32 { v[i] }");
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].code, Code::IndexHotPath);

        let good = findings(
            "rust/src/fleet/protocol.rs",
            "fn f(v: &[f32], i: usize) -> f32 { \
             debug_assert!(i < v.len()); v[i] }",
        );
        assert!(good.is_empty());
    }

    #[test]
    fn tests_inside_hot_modules_are_exempt() {
        let fs = findings(
            "rust/src/jsonx/parse.rs",
            "#[cfg(test)]\nmod tests { fn t() { x.unwrap(); v[0]; } }",
        );
        assert!(fs.is_empty());
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let fs = findings("rust/src/runtime/client.rs",
                          "fn f() { x.unwrap_or(0); }");
        assert!(fs.is_empty());
    }

    #[test]
    fn wire_transport_modules_stay_on_the_hot_path() {
        // the TCP transport runs unattended for hours: a panic in the
        // codec or the socket loops kills a live fleet worker, so these
        // files must never fall out of the hot-path prefix list
        for p in [
            "rust/src/fleet/wire.rs",
            "rust/src/fleet/tcp.rs",
            "rust/src/fleet/transport.rs",
            "rust/src/fleet/worker.rs",
            "rust/src/fleet/coordinator.rs",
            "rust/src/fleet/sim.rs",
        ] {
            assert!(is_hot_path(p), "{p} must be hot-path covered");
        }
        let fs = findings("rust/src/fleet/wire.rs",
                          "fn f(b: &[u8]) -> u8 { b[0] }");
        assert_eq!(fs.len(), 1, "codec indexing must stay guarded");
        assert_eq!(fs[0].code, Code::IndexHotPath);
    }
}
