//! Artifact contract checks (TZ-ART001..004): driver code vs committed
//! manifests.
//!
//! Drivers reference executables by name (`rt.prepared("mezo_loss_pm")`)
//! and bind I/O by `(role, name)` string literals. A typo — or a manifest
//! regenerated with a renamed slot — compiles fine and fails at runtime,
//! possibly hours into a fleet run. These rules cross-check every literal
//! against `artifacts/*/manifest.json` at lint time:
//!
//! * TZ-ART001 — artifact name literal not present in any manifest.
//! * TZ-ART002 — a bound `(role, name, dtype)` slot missing from the
//!   contract. When the enclosing `prepared("X")` names the artifact, the
//!   binding is checked against X in every manifest that defines X; when
//!   the artifact is dynamic (`prepared(artifact)`), the binding is
//!   checked against the union of all manifests' slots.
//! * TZ-ART003 (warn) — a manifest artifact no source literal references.
//! * TZ-ART004 — `*_loss_pm*` artifacts must carry `forward_form` of
//!   `materialize` or `implicit` (the warmup planner dispatches on it).

use crate::findings::{Code, Finding};
use crate::lexer::{Kind, Token};
use crate::manifestx::ManifestContracts;
use crate::source::{matching_close, SourceFile};
use std::collections::BTreeSet;

/// How each binding method consumes its leading string-literal args.
#[derive(Clone, Copy, Debug, PartialEq)]
enum BindShape {
    /// `(role, name, ...)` with an exact dtype requirement (None = any)
    RoleName(Option<&'static str>),
    /// `(role, ...)` — role must exist in the contract
    RoleOnly,
    /// `(name, ...)` — slot is `("scalar", name)` with the given dtype
    ScalarNamed(&'static str),
}

const BINDERS: &[(&str, BindShape)] = &[
    ("bind_buf", BindShape::RoleName(None)),
    ("bind_staged", BindShape::RoleName(None)),
    ("bind_f32", BindShape::RoleName(Some("f32"))),
    ("bind_i32", BindShape::RoleName(Some("i32"))),
    ("bind_bufs", BindShape::RoleOnly),
    ("bind_nth_f32", BindShape::RoleOnly),
    ("bind_scalar_f32", BindShape::ScalarNamed("f32")),
    ("bind_scalar_u32", BindShape::ScalarNamed("u32")),
];

pub const VALID_FORWARD_FORMS: &[&str] = &["materialize", "implicit"];

/// Full artifact pass over the file set + manifests.
pub fn check(files: &[SourceFile], manifests: &[ManifestContracts],
             out: &mut Vec<Finding>) {
    let known: BTreeSet<&str> = manifests
        .iter()
        .flat_map(|m| m.artifacts.keys())
        .map(String::as_str)
        .collect();
    let mut referenced: BTreeSet<String> = BTreeSet::new();

    for file in files {
        check_file(file, manifests, &known, &mut referenced, out);
        // any string literal equal to an artifact name counts as a
        // reference (e.g. the loss_artifact dispatch table in manifest.rs)
        for t in &file.tokens {
            if t.kind == Kind::Str && known.contains(t.text.as_str()) {
                referenced.insert(t.text.clone());
            }
        }
    }

    for m in manifests {
        for (name, art) in &m.artifacts {
            if !referenced.contains(name) {
                out.push(Finding::new(
                    Code::ArtUnreferenced,
                    &m.path,
                    0,
                    format!("artifact `{name}` is not referenced by any \
                             source literal — dead contract?"),
                ));
            }
            let is_loss = name.contains("_loss_pm");
            match art.forward_form.as_deref() {
                None if is_loss => out.push(Finding::new(
                    Code::ArtForwardForm,
                    &m.path,
                    0,
                    format!("loss artifact `{name}` has no `forward_form` \
                             (expected one of {VALID_FORWARD_FORMS:?})"),
                )),
                Some(f) if !VALID_FORWARD_FORMS.contains(&f) => {
                    out.push(Finding::new(
                        Code::ArtForwardForm,
                        &m.path,
                        0,
                        format!("artifact `{name}` has unknown forward_form \
                                 `{f}` (expected one of {VALID_FORWARD_FORMS:?})"),
                    ))
                }
                _ => {}
            }
        }
    }
}

/// The artifact context a binding call resolves against.
enum Ctx {
    /// `prepared("name")` — check against that artifact, per manifest
    Literal(String),
    /// `prepared(expr)` or no prepared in scope — union check
    Dynamic,
}

fn check_file(file: &SourceFile, manifests: &[ManifestContracts],
              known: &BTreeSet<&str>, referenced: &mut BTreeSet<String>,
              out: &mut Vec<Finding>) {
    let ts = &file.tokens;
    let mut ctx = Ctx::Dynamic;
    // the prepared() context is per-function: past this token index the
    // context resets to Dynamic
    let mut ctx_end = 0usize;
    for i in 0..ts.len() {
        if i > ctx_end {
            ctx = Ctx::Dynamic;
            ctx_end = usize::MAX;
        }
        if file.masked[i] || ts[i].kind != Kind::Ident {
            continue;
        }
        let name = ts[i].text.as_str();

        if name == "prepared" && ts.get(i + 1).is_some_and(|t| t.is_punct('(')) {
            ctx_end = file.enclosing_fn(i).map_or(usize::MAX, |(_, end)| end);
            ctx = match first_literal_arg(ts, i + 1) {
                Some(lit) => {
                    referenced.insert(lit.text.clone());
                    if !known.contains(lit.text.as_str()) {
                        out.push(Finding::new(
                            Code::ArtUnknownName,
                            &file.path,
                            lit.line,
                            format!("artifact `{}` not found in any committed \
                                     manifest", lit.text),
                        ));
                    }
                    Ctx::Literal(lit.text.clone())
                }
                None => Ctx::Dynamic,
            };
            continue;
        }

        if name == "warmup" && ts.get(i + 1).is_some_and(|t| t.is_punct('(')) {
            let close = matching_close(ts, i + 1);
            for t in &ts[i + 1..close] {
                if t.kind == Kind::Str {
                    referenced.insert(t.text.clone());
                    if !known.contains(t.text.as_str()) {
                        out.push(Finding::new(
                            Code::ArtUnknownName,
                            &file.path,
                            t.line,
                            format!("warmup artifact `{}` not found in any \
                                     committed manifest", t.text),
                        ));
                    }
                }
            }
            continue;
        }

        let Some((_, shape)) = BINDERS.iter().find(|(b, _)| *b == name) else {
            continue;
        };
        if !ts.get(i + 1).is_some_and(|t| t.is_punct('(')) {
            continue;
        }
        // `pub fn bind_*` definitions have ident (not literal) args and
        // fall out of literal extraction naturally
        let close = matching_close(ts, i + 1);
        let lits = leading_literal_args(&ts[i + 2..close]);
        let slot = match shape {
            BindShape::RoleName(dtype) => match (lits.first(), lits.get(1)) {
                (Some(role), Some(n)) => {
                    Some((role.text.clone(), Some(n.text.clone()), *dtype, n.line))
                }
                _ => None,
            },
            BindShape::RoleOnly => lits
                .first()
                .map(|role| (role.text.clone(), None, None, role.line)),
            BindShape::ScalarNamed(dtype) => lits.first().map(|n| {
                ("scalar".to_string(), Some(n.text.clone()), Some(*dtype), n.line)
            }),
        };
        let Some((role, slot_name, dtype, line)) = slot else { continue };
        check_slot(&ctx, manifests, &file.path, name, &role,
                   slot_name.as_deref(), dtype, line, out);
    }
}

#[allow(clippy::too_many_arguments)]
fn check_slot(ctx: &Ctx, manifests: &[ManifestContracts], file: &str,
              binder: &str, role: &str, slot_name: Option<&str>,
              dtype: Option<&str>, line: u32, out: &mut Vec<Finding>) {
    let mut checked_any = false;
    match ctx {
        Ctx::Literal(artifact) => {
            for m in manifests {
                let Some(art) = m.artifacts.get(artifact) else { continue };
                checked_any = true;
                let ok = match slot_name {
                    Some(n) => {
                        art.has_input(role, n)
                            && dtype.map_or(true, |d| art.input_dtype(role, n) == Some(d))
                    }
                    None => art.has_input_role(role),
                };
                if !ok {
                    out.push(Finding::new(
                        Code::ArtSlotMismatch,
                        file,
                        line,
                        format!("{binder}: slot ({role}, {}{}) not in \
                                 `{artifact}` inputs of {}",
                                slot_name.unwrap_or("*"),
                                dtype.map(|d| format!(", {d}")).unwrap_or_default(),
                                m.path),
                    ));
                }
            }
            // an unknown artifact already produced TZ-ART001; don't cascade
            let _ = checked_any;
        }
        Ctx::Dynamic => {
            let ok = manifests.iter().any(|m| {
                m.artifacts.values().any(|art| match slot_name {
                    Some(n) => {
                        art.has_input(role, n)
                            && dtype.map_or(true, |d| art.input_dtype(role, n) == Some(d))
                    }
                    None => art.has_input_role(role),
                })
            });
            if !manifests.is_empty() && !ok {
                out.push(Finding::new(
                    Code::ArtSlotMismatch,
                    file,
                    line,
                    format!("{binder}: slot ({role}, {}{}) not in any \
                             artifact of any committed manifest",
                            slot_name.unwrap_or("*"),
                            dtype.map(|d| format!(", {d}")).unwrap_or_default()),
                ));
            }
        }
    }
}

/// The first string literal inside a balanced `( ... )` group, if the
/// argument expression starts with one (i.e. a literal call, not a
/// variable).
fn first_literal_arg(ts: &[Token], open: usize) -> Option<&Token> {
    let close = matching_close(ts, open);
    ts[open + 1..close].iter().find(|t| t.kind == Kind::Str)
}

/// Leading comma-separated args (depth 0) that are string literals; stops
/// at the first non-literal argument.
fn leading_literal_args(arg_tokens: &[Token]) -> Vec<&Token> {
    let mut out = Vec::new();
    let mut depth = 0i64;
    let mut arg_start = true;
    for t in arg_tokens {
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
        } else if depth == 0 && t.is_punct(',') {
            arg_start = true;
            continue;
        } else if depth == 0 && arg_start {
            if t.kind == Kind::Str {
                out.push(t);
                arg_start = false;
            } else {
                break; // first non-literal argument ends the prefix
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "artifacts": {
        "mezo_loss_pm": {
          "file": "m.hlo.txt",
          "forward_form": "materialize",
          "inputs": [
            {"role": "param", "name": "w", "shape": [2], "dtype": "f32"},
            {"role": "batch", "name": "tokens", "shape": [4], "dtype": "i32"},
            {"role": "scalar", "name": "seed", "shape": [], "dtype": "u32"},
            {"role": "scalar", "name": "rho", "shape": [], "dtype": "f32"}
          ],
          "outputs": [
            {"role": "scalar", "name": "loss_pair", "shape": [2], "dtype": "f32"}
          ]
        }
      }
    }"#;

    fn lint(src: &str) -> Vec<Finding> {
        let files = vec![SourceFile::new("rust/src/d.rs".into(), src)];
        let ms = vec![ManifestContracts::from_json("m.json", MANIFEST).unwrap()];
        let mut out = Vec::new();
        check(&files, &ms, &mut out);
        out
    }

    #[test]
    fn clean_driver_passes() {
        let fs = lint(
            "fn f(rt: &Rt) -> Result<()> { \
             let mut call = rt.prepared(\"mezo_loss_pm\")?; \
             call.bind_bufs(\"param\", bufs)?; \
             call.bind_i32(\"batch\", \"tokens\", &toks, a)?; \
             call.bind_scalar_u32(\"seed\", s, a)?; \
             call.bind_scalar_f32(\"rho\", r, a)?; Ok(()) }",
        );
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn unknown_artifact_and_bad_slot() {
        let fs = lint(
            "fn f(rt: &Rt) { let c = rt.prepared(\"mezo_loss\"); \
             let mut c2 = rt.prepared(\"mezo_loss_pm\"); \
             c2.bind_scalar_f32(\"learning_rate\", x, a); }",
        );
        assert!(fs.iter().any(|f| f.code == Code::ArtUnknownName));
        assert!(fs.iter().any(|f| f.code == Code::ArtSlotMismatch
                                  && f.message.contains("learning_rate")));
    }

    #[test]
    fn dtype_mismatch_is_flagged() {
        let fs = lint(
            "fn f(c: &mut Call) { let mut c = rt.prepared(\"mezo_loss_pm\"); \
             c.bind_scalar_f32(\"seed\", x, a); }",
        );
        assert!(fs.iter().any(|f| f.code == Code::ArtSlotMismatch));
    }

    #[test]
    fn dynamic_context_uses_union() {
        // helper without prepared() in scope: union check
        let ok = lint("fn bind_batch(c: &mut Call) { \
                       c.bind_i32(\"batch\", \"tokens\", t, a); }");
        assert!(ok.is_empty(), "{ok:?}");
        let bad = lint("fn bind_batch(c: &mut Call) { \
                        c.bind_i32(\"batch\", \"tokns\", t, a); }");
        assert!(bad.iter().any(|f| f.code == Code::ArtSlotMismatch));
    }

    #[test]
    fn unreferenced_artifact_warns() {
        let fs = lint("fn f() {}");
        assert!(fs.iter().any(|f| f.code == Code::ArtUnreferenced
                                  && f.message.contains("mezo_loss_pm")));
    }

    #[test]
    fn forward_form_required_on_loss_artifacts() {
        let m = r#"{"artifacts": {"x_loss_pm": {"file": "x",
                    "inputs": [], "outputs": []}}}"#;
        let ms = vec![ManifestContracts::from_json("m.json", m).unwrap()];
        let files = vec![SourceFile::new("d.rs".into(),
                                         "fn f() { rt.prepared(\"x_loss_pm\"); }")];
        let mut out = Vec::new();
        check(&files, &ms, &mut out);
        assert!(out.iter().any(|f| f.code == Code::ArtForwardForm));
    }

    #[test]
    fn warmup_names_are_checked() {
        let fs = lint("fn f(rt: &Rt) { rt.warmup(&[\"mezo_loss_pm\", \"nope\"]); }");
        assert!(fs.iter().any(|f| f.code == Code::ArtUnknownName
                                  && f.message.contains("nope")));
        assert!(!fs.iter().any(|f| f.message.contains("mezo_loss_pm")
                                   && f.code == Code::ArtUnknownName));
    }
}
