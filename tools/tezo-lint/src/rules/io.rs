//! Durable-IO discipline (TZ-IO001).
//!
//! PR 10 routes every hot-path file creation through `runtime::durable`
//! (temp + fsync + atomic rename, with failpoint injection for the crash
//! battery): a raw `std::fs::write` or `File::create` on the training hot
//! path can leave a torn file that a later run trusts as a checkpoint or
//! journal. Reads, directory ops, removals, and in-place truncation stay
//! free — torn-tolerance is a file-*creation* problem.
//!
//! * TZ-IO001 — `fs::write(..)` or `File::create(..)`/`File::create_new(..)`
//!   in a hot-path module (see [`super::panics::is_hot_path`]), outside
//!   `runtime/durable.rs` (the one legal raw writer) and test code.

use crate::findings::{Code, Finding};
use crate::lexer::Kind;
use crate::rules::panics::is_hot_path;
use crate::source::SourceFile;

/// The durable-IO module itself is the one place raw writes are the point.
fn exempt(path: &str) -> bool {
    path.contains("runtime/durable.rs")
}

/// Does the path segment before token `i` read `<owner> ::`? (`::` lexes
/// as two `:` puncts.)
fn owned_by(file: &SourceFile, i: usize, owners: &[&str]) -> bool {
    if i < 3 {
        return false;
    }
    let ts = &file.tokens;
    ts[i - 1].is_punct(':')
        && ts[i - 2].is_punct(':')
        && ts[i - 3].kind == Kind::Ident
        && owners.contains(&ts[i - 3].text.as_str())
}

pub fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if !is_hot_path(&file.path) || exempt(&file.path) {
        return;
    }
    for (i, t) in file.tokens.iter().enumerate() {
        if file.masked[i] || t.kind != Kind::Ident {
            continue;
        }
        let name = t.text.as_str();
        let owner = if name == "write" && owned_by(file, i, &["fs"]) {
            "fs"
        } else if (name == "create" || name == "create_new")
            && owned_by(file, i, &["File"])
        {
            "File"
        } else {
            continue;
        };
        out.push(Finding::new(
            Code::IoRawWrite,
            &file.path,
            t.line,
            format!("raw `{owner}::{name}` on the hot path — route the \
                     write through runtime::durable (write_atomic / \
                     open_append) so a crash cannot leave a torn file"),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn flags_raw_writes_on_the_hot_path() {
        let fs = findings(
            "rust/src/runtime/checkpoint.rs",
            "fn f() { std::fs::write(p, b)?; let f = File::create(p)?; \
             let g = fs::File::create_new(q)?; }",
        );
        assert_eq!(fs.len(), 3, "{fs:?}");
        assert!(fs.iter().all(|f| f.code == Code::IoRawWrite));
    }

    #[test]
    fn reads_and_dir_ops_are_fine() {
        let fs = findings(
            "rust/src/runtime/checkpoint.rs",
            "fn f() { let b = std::fs::read(p)?; std::fs::create_dir_all(d)?; \
             std::fs::remove_file(p)?; let s = std::fs::read_to_string(p)?; }",
        );
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn durable_module_and_cold_paths_are_exempt() {
        let src = "fn f() { std::fs::write(p, b)?; let f = File::create(t)?; }";
        assert!(findings("rust/src/runtime/durable.rs", src).is_empty());
        assert!(findings("rust/src/telemetry/export.rs", src).is_empty());
        assert!(findings("rust/benches/bench_io.rs", src).is_empty());
    }

    #[test]
    fn test_code_is_masked() {
        let fs = findings(
            "rust/src/runtime/journal.rs",
            "#[cfg(test)]\nmod tests { fn t() { std::fs::write(p, b).unwrap(); } }",
        );
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn unrelated_write_idents_are_fine() {
        // method calls and other owners must not trip the pattern
        let fs = findings(
            "rust/src/runtime/journal.rs",
            "fn f() { buf.write(b)?; w.write_all(b)?; durable::write_atomic(p, b)?; \
             Journal::create_entry(); }",
        );
        assert!(fs.is_empty(), "{fs:?}");
    }
}
