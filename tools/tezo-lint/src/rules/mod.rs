//! Rule passes. Each pass walks the token stream of one `SourceFile`
//! (or, for the artifact rules, the whole file set plus the committed
//! manifests) and appends `Finding`s. Test-masked tokens are never
//! flagged — test code is allowed to unwrap, index, and hash freely.

pub mod artifacts;
pub mod determinism;
pub mod io;
pub mod obs;
pub mod panics;
pub mod rng_time;
pub mod tune;

use crate::lexer::Token;

/// Token range of the statement containing index `i`: from just after the
/// previous `;`/`{`/`}` through the next `;` (or block edge). Used by
/// co-occurrence heuristics ("X and Y in the same statement").
pub fn statement_around(tokens: &[Token], i: usize) -> (usize, usize) {
    let mut lo = i;
    while lo > 0 {
        let t = &tokens[lo - 1];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            break;
        }
        lo -= 1;
    }
    let mut hi = i;
    while hi + 1 < tokens.len() {
        let t = &tokens[hi + 1];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            break;
        }
        hi += 1;
    }
    (lo, hi)
}

/// Is the token at `i` a method call `.name(`? (preceded by `.`, followed
/// by `(`) — distinguishes `x.unwrap()` from a fn named `unwrap`.
pub fn is_method_call(tokens: &[Token], i: usize) -> bool {
    i > 0
        && tokens[i - 1].is_punct('.')
        && tokens.get(i + 1).is_some_and(|t| t.is_punct('('))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn statement_bounds() {
        let ts = lex("a(); let x = b.c(d); e();");
        let c = ts.iter().position(|t| t.is_ident("c")).unwrap();
        let (lo, hi) = statement_around(&ts, c);
        assert!(ts[lo].is_ident("let"));
        assert!(ts[hi].is_punct(')'));
        assert!(is_method_call(&ts, c));
    }
}
