//! Forward-form literal discipline (TZ-TUNE001).
//!
//! PR 9 makes `--forward-form auto` the default: the concrete form is a
//! *decision* — parsed into `config::FormPolicy`, resolved by
//! `runtime::tune`, and shipped pinned through the fleet handshake. A raw
//! `"implicit"` / `"materialize"` string anywhere else is a dispatch path
//! bypassing that resolution: it silently disagrees with the tuning table
//! and, in fleet code, can break bitwise parity between workers. Only two
//! places may spell the words: `config/` (the parser/printer that owns
//! the vocabulary) and `runtime/tune.rs` (the tuner's own span names and
//! table codec). Everyone else goes through `ForwardForm::name()` /
//! `FormPolicy::parse` / the resolved `Resolution`.
//!
//! The check is exact-match on string-literal *contents* — prose like
//! `"two-point loss form: implicit|materialize"` in a help string does
//! not trip it, and test-masked code is exempt like every other rule.

use crate::findings::{Code, Finding};
use crate::source::SourceFile;

/// The `ForwardForm::parse` vocabulary plus the `auto` policy word.
const DENIED: &[&str] = &["implicit", "materialize", "materialized", "dense",
                          "auto"];

/// The two owners of the vocabulary (see module docs).
fn exempt(path: &str) -> bool {
    path.contains("/config/") || path.ends_with("runtime/tune.rs")
}

pub fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    if exempt(&file.path) {
        return;
    }
    for (i, t) in file.tokens.iter().enumerate() {
        if file.masked[i] || t.kind != crate::lexer::Kind::Str {
            continue;
        }
        if DENIED.contains(&t.text.as_str()) {
            out.push(Finding::new(
                Code::TuneFormLiteral,
                &file.path,
                t.line,
                format!("raw forward-form literal {:?} — parse it with \
                         `FormPolicy::parse` / compare via \
                         `ForwardForm::name()` so the dispatch agrees with \
                         the tuning table (see docs/runtime.md \"Autotuning\")",
                        t.text),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn flags_exact_form_literals_outside_the_owners() {
        let fs = findings(
            "rust/src/fleet/worker.rs",
            "fn f() { let form = \"implicit\"; dispatch(\"materialize\"); \
             let p = \"auto\"; }",
        );
        assert_eq!(fs.len(), 3);
        assert!(fs.iter().all(|f| f.code == Code::TuneFormLiteral));
    }

    #[test]
    fn config_and_tune_own_the_vocabulary() {
        for path in ["rust/src/config/mod.rs", "rust/src/runtime/tune.rs"] {
            assert!(findings(path, "const A: &str = \"implicit\";").is_empty(),
                    "{path} should be exempt");
        }
        // but the rest of runtime/ is not
        assert_eq!(findings("rust/src/runtime/client.rs",
                            "const A: &str = \"implicit\";").len(), 1);
    }

    #[test]
    fn prose_and_compound_strings_are_fine() {
        let fs = findings(
            "rust/src/main.rs",
            "fn f() { help(\"two-point loss form: auto|implicit|materialize\"); \
             name(\"tezo_loss_pm_implicit\"); }",
        );
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn test_code_is_masked() {
        let fs = findings(
            "rust/src/fleet/worker.rs",
            "#[test]\nfn t() { assert_eq!(tag, \"materialize\"); }",
        );
        assert!(fs.is_empty(), "{fs:?}");
    }
}
