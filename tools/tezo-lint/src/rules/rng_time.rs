//! RNG / time discipline (TZ-RNG001..003).
//!
//! The training stack is seed-deterministic end to end: every stochastic
//! quantity derives from `rngx` streams keyed by the run seed, and the
//! fleet protocol syncs scalar seeds, not tensors. Ambient entropy or
//! wall-clock values flowing into numeric state silently break replay and
//! worker agreement, so they are banned statically:
//!
//! * TZ-RNG001 — ambient randomness identifiers (`rand`, `getrandom`,
//!   `OsRng`, `thread_rng`, `from_entropy`, `RandomState`, ...) anywhere
//!   outside `rngx/` (the one module allowed to define randomness).
//! * TZ-RNG002 — wall-clock sources (`SystemTime`, `UNIX_EPOCH`) outside
//!   `benchkit` and metrics modules (which may timestamp reports).
//! * TZ-RNG003 — a monotonic-clock reading (`elapsed`, `as_nanos`, ...)
//!   in the same statement as a seed/RNG/hash sink. Timing for metrics is
//!   fine; timing entropy feeding numeric state is not.

use crate::findings::{Code, Finding};
use crate::rules::statement_around;
use crate::source::SourceFile;

const AMBIENT: &[&str] = &[
    "rand", "random", "getrandom", "OsRng", "SmallRng", "StdRng",
    "ThreadRng", "thread_rng", "from_entropy", "RandomState",
];

const WALL_CLOCK: &[&str] = &["SystemTime", "UNIX_EPOCH"];

/// Monotonic-clock readings that yield numbers.
const CLOCK_READS: &[&str] = &[
    "as_nanos", "as_micros", "subsec_nanos", "subsec_micros", "elapsed",
];

/// Identifiers that mark numeric/seed state sinks.
const SEED_SINKS: &[&str] = &["seed", "seeds", "rng", "hash", "entropy"];

/// Does `path` identify the module that is allowed to define randomness?
fn in_rngx(path: &str) -> bool {
    path.contains("/rngx/") || path.ends_with("/rngx.rs")
}

/// Timing/reporting modules may read wall-clock time.
fn in_timing_module(path: &str) -> bool {
    path.contains("/benchkit/") || path.contains("metrics")
}

pub fn check(file: &SourceFile, out: &mut Vec<Finding>) {
    let ambient_ok = in_rngx(&file.path);
    let wall_ok = in_rngx(&file.path) || in_timing_module(&file.path);

    for (i, t) in file.tokens.iter().enumerate() {
        if file.masked[i] || t.kind != crate::lexer::Kind::Ident {
            continue;
        }
        let name = t.text.as_str();

        if !ambient_ok && AMBIENT.contains(&name) {
            out.push(Finding::new(
                Code::RngAmbient,
                &file.path,
                t.line,
                format!("ambient randomness `{name}` outside rngx/ — derive \
                         from the run seed via rngx streams instead"),
            ));
            continue;
        }

        if !wall_ok && WALL_CLOCK.contains(&name) {
            out.push(Finding::new(
                Code::RngWallClock,
                &file.path,
                t.line,
                format!("wall-clock source `{name}` outside benchkit/metrics \
                         — wall time must never reach numeric state"),
            ));
            continue;
        }

        if CLOCK_READS.contains(&name) {
            let (lo, hi) = statement_around(&file.tokens, i);
            let sink = file.tokens[lo..=hi].iter().find(|s| {
                s.kind == crate::lexer::Kind::Ident
                    && SEED_SINKS.iter().any(|k| {
                        let id = s.text.to_ascii_lowercase();
                        id == *k || id.starts_with(&format!("{k}_"))
                            || id.ends_with(&format!("_{k}"))
                    })
            });
            if let Some(s) = sink {
                out.push(Finding::new(
                    Code::RngTimeSeed,
                    &file.path,
                    t.line,
                    format!("clock reading `{name}` flows into `{}` — time \
                             must not seed numeric state", s.text),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(path: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::new(path.into(), src);
        let mut out = Vec::new();
        check(&f, &mut out);
        out
    }

    #[test]
    fn flags_ambient_rng_outside_rngx() {
        let fs = findings("rust/src/coordinator/step.rs",
                          "fn f() { let r = rand::thread_rng(); }");
        assert_eq!(fs.len(), 2); // `rand` + `thread_rng`
        assert!(fs.iter().all(|f| f.code == Code::RngAmbient));
    }

    #[test]
    fn rngx_is_exempt() {
        assert!(findings("rust/src/rngx/mod.rs", "fn f() { OsRng; }").is_empty());
    }

    #[test]
    fn flags_wall_clock_and_time_seed() {
        let fs = findings(
            "rust/src/fleet/worker.rs",
            "fn f() { let t = SystemTime::now(); \
             let seed = start.elapsed().as_nanos() as u64; }",
        );
        assert!(fs.iter().any(|f| f.code == Code::RngWallClock));
        assert!(fs.iter().any(|f| f.code == Code::RngTimeSeed));
    }

    #[test]
    fn pure_timing_is_fine() {
        let fs = findings(
            "rust/src/fleet/coordinator.rs",
            "fn f() { let dt = start.elapsed().as_secs_f64(); record(dt); }",
        );
        assert!(fs.is_empty());
    }

    #[test]
    fn test_code_is_masked() {
        let fs = findings("rust/src/coordinator/step.rs",
                          "#[test]\nfn t() { let r = thread_rng(); }");
        assert!(fs.is_empty());
    }
}
