//! Source-file model shared by every rule: the token stream plus
//! structural annotations — which tokens are test-only code, which
//! function body a token belongs to, and balanced-delimiter scanning.

use crate::lexer::{lex, Kind, Token};

/// One analyzed Rust file.
pub struct SourceFile {
    /// path as reported in findings (repo-relative where possible)
    pub path: String,
    pub tokens: Vec<Token>,
    /// `masked[i]` — token i is inside `#[cfg(test)]` / `#[test]` code
    pub masked: Vec<bool>,
    /// body token ranges (open-brace..=close-brace) of every `fn`
    pub fn_bodies: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn new(path: String, src: &str) -> SourceFile {
        let tokens = lex(src);
        let masked = mask_test_regions(&tokens);
        let fn_bodies = find_fn_bodies(&tokens);
        SourceFile { path, tokens, masked, fn_bodies }
    }

    /// Body range of the innermost function containing token `i`.
    pub fn enclosing_fn(&self, i: usize) -> Option<(usize, usize)> {
        self.fn_bodies
            .iter()
            .filter(|(a, b)| *a <= i && i <= *b)
            .min_by_key(|(a, b)| b - a)
            .copied()
    }

    /// Does the innermost function around token `i` contain any of the
    /// given identifier tokens? (Used for "bounds-awareness" heuristics.)
    pub fn fn_contains_ident(&self, i: usize, names: &[&str]) -> bool {
        let Some((a, b)) = self.enclosing_fn(i) else { return false };
        self.tokens[a..=b]
            .iter()
            .any(|t| t.kind == Kind::Ident && names.contains(&t.text.as_str()))
    }
}

/// Index of the delimiter that closes the one at `open` (`tokens[open]`
/// must be `(`, `[` or `{`). Returns the last token on imbalance.
pub fn matching_close(tokens: &[Token], open: usize) -> usize {
    let (o, c) = match tokens[open].text.as_str() {
        "(" => ('(', ')'),
        "[" => ('[', ']'),
        _ => ('{', '}'),
    };
    let mut depth = 0i64;
    for (j, t) in tokens.iter().enumerate().skip(open) {
        if t.is_punct(o) {
            depth += 1;
        } else if t.is_punct(c) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    tokens.len().saturating_sub(1)
}

/// Mark every token inside test-only regions:
/// * `#[cfg(test)]` followed by `mod name { ... }` — the whole module;
/// * `#[test]` / `#[should_panic]` attributes — the following `fn` body
///   (plus the attribute itself).
fn mask_test_regions(tokens: &[Token]) -> Vec<bool> {
    let mut masked = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if tokens[i].is_punct('#') && i + 1 < tokens.len() && tokens[i + 1].is_punct('[') {
            let close = matching_close(tokens, i + 1);
            let attr: Vec<&str> = tokens[i + 2..close]
                .iter()
                .filter(|t| t.kind == Kind::Ident)
                .map(|t| t.text.as_str())
                .collect();
            let is_cfg_test = attr.first() == Some(&"cfg") && attr.contains(&"test");
            let is_test_attr = attr.first() == Some(&"test")
                || attr.first() == Some(&"should_panic");
            if is_cfg_test || is_test_attr {
                // mask from the attribute through the end of the item it
                // decorates (the next brace-balanced block)
                if let Some(open) = next_item_open_brace(tokens, close + 1) {
                    let end = matching_close(tokens, open);
                    for m in masked.iter_mut().take(end + 1).skip(i) {
                        *m = true;
                    }
                    i = end + 1;
                    continue;
                }
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    masked
}

/// First `{` that opens the decorated item's body, skipping over further
/// attributes and the item header (which may contain `(..)` parameter
/// lists but no bare `{`).
fn next_item_open_brace(tokens: &[Token], from: usize) -> Option<usize> {
    let mut i = from;
    while i < tokens.len() {
        let t = &tokens[i];
        if t.is_punct('#') && i + 1 < tokens.len() && tokens[i + 1].is_punct('[') {
            i = matching_close(tokens, i + 1) + 1;
            continue;
        }
        if t.is_punct('(') || t.is_punct('[') {
            i = matching_close(tokens, i) + 1;
            continue;
        }
        if t.is_punct('{') {
            return Some(i);
        }
        if t.is_punct(';') {
            return None; // item without a body (e.g. `mod foo;`)
        }
        i += 1;
    }
    None
}

/// Body ranges of every `fn item` (including closures is unnecessary: the
/// heuristics only need "somewhere in this function").
fn find_fn_bodies(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if tokens[i].is_ident("fn") {
            // scan forward to the body's `{`, skipping the signature;
            // `where` clauses and generics contain no bare `{`
            let mut j = i + 1;
            while j < tokens.len() {
                if tokens[j].is_punct('(') || tokens[j].is_punct('[') {
                    j = matching_close(tokens, j) + 1;
                    continue;
                }
                if tokens[j].is_punct('{') {
                    out.push((j, matching_close(tokens, j)));
                    break;
                }
                if tokens[j].is_punct(';') {
                    break; // trait method declaration without a body
                }
                j += 1;
            }
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_cfg_test_modules() {
        let src = "fn live() { a.unwrap(); }\n\
                   #[cfg(test)]\nmod tests {\n fn t() { b.unwrap(); }\n}";
        let f = SourceFile::new("x.rs".into(), src);
        let unmasked: Vec<&str> = f
            .tokens
            .iter()
            .zip(&f.masked)
            .filter(|(_, m)| !**m)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(unmasked.contains(&"live"));
        assert!(!unmasked.contains(&"b"));
    }

    #[test]
    fn masks_test_fns_only() {
        let src = "#[test]\nfn t() { x.unwrap(); }\nfn live() { y(); }";
        let f = SourceFile::new("x.rs".into(), src);
        let live: Vec<&str> = f
            .tokens
            .iter()
            .zip(&f.masked)
            .filter(|(_, m)| !**m)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(!live.contains(&"unwrap"));
        assert!(live.contains(&"live"));
    }

    #[test]
    fn fn_bodies_and_enclosing() {
        let src = "fn a(x: usize) { inner(); }\nfn b() { other(); }";
        let f = SourceFile::new("x.rs".into(), src);
        assert_eq!(f.fn_bodies.len(), 2);
        let inner = f.tokens.iter().position(|t| t.is_ident("inner")).unwrap();
        let (lo, hi) = f.enclosing_fn(inner).unwrap();
        assert!(lo < inner && inner < hi);
        assert!(f.fn_contains_ident(inner, &["inner"]));
        assert!(!f.fn_contains_ident(inner, &["other"]));
    }
}
