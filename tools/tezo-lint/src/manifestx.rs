//! Minimal JSON reader + the manifest slot model for `artifact-lint`.
//!
//! tezo-lint cannot depend on the `tezo` crate (that would pull in the
//! PJRT toolchain), so it carries its own small recursive-descent JSON
//! parser — enough for `artifacts/*/manifest.json`, which is machine
//! written and well-formed. Parse errors are reported, never panicked on.

use std::collections::BTreeMap;

// ---------------------------------------------------------------- JSON --

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap: manifest key order is irrelevant and iteration must be
    /// deterministic (the lint holds itself to its own TZ-DET001 rule)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

pub fn parse_json(src: &str) -> Result<Json, String> {
    let b = src.as_bytes();
    let mut p = P { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(format!("trailing bytes at offset {}", p.i));
    }
    Ok(v)
}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> P<'a> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.num(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("expected `{word}` at offset {}", self.i))
        }
    }

    fn num(&mut self) -> Result<Json, String> {
        let start = self.i;
        while matches!(self.b.get(self.i),
                       Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| "non-utf8 number".to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{s}` at offset {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.i += 1; // opening quote
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    let esc = self.b.get(self.i + 1).copied();
                    match esc {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self.b.get(self.i + 2..self.i + 6)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .and_then(char::from_u32)
                                .ok_or("bad \\u escape")?;
                            out.push(hex);
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 2;
                }
                Some(&c) => {
                    // pass UTF-8 bytes through; manifests are ASCII anyway
                    out.push(c as char);
                    self.i += 1;
                }
            }
        }
    }

    fn arr(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut out = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected `,` or `]` at offset {}", self.i)),
            }
        }
    }

    fn obj(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut out = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            if self.b.get(self.i) != Some(&b'"') {
                return Err(format!("expected key at offset {}", self.i));
            }
            let key = self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected `:` at offset {}", self.i));
            }
            self.i += 1;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected `,` or `}}` at offset {}", self.i)),
            }
        }
    }
}

// ----------------------------------------------------------- manifests --

/// One `(role, name, dtype)` slot of an artifact's I/O contract.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Slot {
    pub role: String,
    pub name: String,
    pub dtype: String,
}

/// One executable artifact's contract, as committed in a manifest.
#[derive(Clone, Debug)]
pub struct ArtifactContract {
    pub name: String,
    pub inputs: Vec<Slot>,
    pub outputs: Vec<Slot>,
    pub forward_form: Option<String>,
}

impl ArtifactContract {
    pub fn has_input(&self, role: &str, name: &str) -> bool {
        self.inputs.iter().any(|s| s.role == role && s.name == name)
    }

    pub fn input_dtype(&self, role: &str, name: &str) -> Option<&str> {
        self.inputs
            .iter()
            .find(|s| s.role == role && s.name == name)
            .map(|s| s.dtype.as_str())
    }

    /// Does this artifact take any input of the given role?
    pub fn has_input_role(&self, role: &str) -> bool {
        self.inputs.iter().any(|s| s.role == role)
    }
}

/// The artifact-contract view of one `manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestContracts {
    /// manifest path as shown in findings
    pub path: String,
    /// keyed by artifact name; BTreeMap for deterministic iteration
    pub artifacts: BTreeMap<String, ArtifactContract>,
}

impl ManifestContracts {
    pub fn from_json(path: &str, src: &str) -> Result<ManifestContracts, String> {
        let doc = parse_json(src)?;
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("manifest has no `artifacts` object")?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in arts {
            artifacts.insert(
                name.clone(),
                ArtifactContract {
                    name: name.clone(),
                    inputs: slots(entry.get("inputs"))?,
                    outputs: slots(entry.get("outputs"))?,
                    forward_form: entry
                        .get("forward_form")
                        .and_then(Json::as_str)
                        .map(str::to_string),
                },
            );
        }
        Ok(ManifestContracts { path: path.to_string(), artifacts })
    }
}

fn slots(v: Option<&Json>) -> Result<Vec<Slot>, String> {
    let arr = v.and_then(Json::as_arr).ok_or("artifact entry missing io list")?;
    let mut out = Vec::with_capacity(arr.len());
    for s in arr {
        let field = |k: &str| -> Result<String, String> {
            s.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("io slot missing `{k}`"))
        };
        out.push(Slot { role: field("role")?, name: field("name")?, dtype: field("dtype")? });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "config": {"name": "t"},
      "artifacts": {
        "mezo_loss_pm": {
          "file": "mezo_loss_pm.hlo.txt",
          "forward_form": "materialize",
          "inputs": [
            {"role": "param", "name": "w", "shape": [2, 2], "dtype": "f32"},
            {"role": "scalar", "name": "seed", "shape": [], "dtype": "u32"}
          ],
          "outputs": [
            {"role": "scalar", "name": "loss_pair", "shape": [2], "dtype": "f32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_manifest_contracts() {
        let m = ManifestContracts::from_json("m.json", MINI).unwrap();
        let a = &m.artifacts["mezo_loss_pm"];
        assert!(a.has_input("scalar", "seed"));
        assert_eq!(a.input_dtype("scalar", "seed"), Some("u32"));
        assert_eq!(a.forward_form.as_deref(), Some("materialize"));
        assert_eq!(a.outputs.len(), 1);
    }

    #[test]
    fn json_scalars_and_errors() {
        assert_eq!(parse_json("[1, -2.5e1, true, null]").unwrap(),
                   Json::Arr(vec![Json::Num(1.0), Json::Num(-25.0),
                                  Json::Bool(true), Json::Null]));
        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("{\"a\": 1} x").is_err());
        assert_eq!(parse_json("\"a\\u0041b\"").unwrap(),
                   Json::Str("aAb".into()));
    }
}
