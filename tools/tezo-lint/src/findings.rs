//! Finding model, rule codes, and report rendering (text + JSON).
//!
//! The JSON writer is hand-rolled (this crate is zero-dependency by
//! design); the emitted shape is stable and machine-readable so CI and
//! later sessions can diff `out/lint_report.json` across commits.

use std::fmt::Write as _;

/// Stable rule identifiers. Every code is documented in
/// `docs/invariants.md`; adding a code there is part of adding it here.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    /// ambient randomness source (`rand`, `getrandom`, `OsRng`, entropy)
    RngAmbient,
    /// wall-clock time source (`SystemTime`, `UNIX_EPOCH`)
    RngWallClock,
    /// monotonic time flowing into seed/hash/numeric state
    RngTimeSeed,
    /// hash-ordered iteration feeding accumulation or protocol emission
    DetHashOrder,
    /// float sort via `partial_cmp().unwrap()` instead of `total_cmp`
    DetPartialSort,
    /// `unwrap`/`expect`/`panic!`-family in a hot-path module
    PanicHotPath,
    /// unguarded identifier indexing in a hot-path module
    IndexHotPath,
    /// artifact name not present in any committed manifest
    ArtUnknownName,
    /// bound `(role, name, dtype)` slot absent from the manifest contract
    ArtSlotMismatch,
    /// manifest artifact never referenced from the Rust sources
    ArtUnreferenced,
    /// loss artifact missing/with unknown `forward_form` tag
    ArtForwardForm,
    /// allowlist entry that matches nothing (stale) or has no justification
    AllowlistStale,
    /// raw clock read outside the telemetry boundary, or a telemetry
    /// readout flowing into seed/wire/kappa state
    ObsClock,
    /// raw forward-form string literal outside `config/`/`runtime/tune.rs`
    /// (dispatch must go through `FormPolicy` / the tuning table)
    TuneFormLiteral,
    /// raw `fs::write`/`File::create` in a hot-path module (durable IO
    /// must go through `runtime::durable`)
    IoRawWrite,
}

impl Code {
    pub fn as_str(self) -> &'static str {
        match self {
            Code::RngAmbient => "TZ-RNG001",
            Code::RngWallClock => "TZ-RNG002",
            Code::RngTimeSeed => "TZ-RNG003",
            Code::DetHashOrder => "TZ-DET001",
            Code::DetPartialSort => "TZ-DET002",
            Code::PanicHotPath => "TZ-PANIC001",
            Code::IndexHotPath => "TZ-PANIC002",
            Code::ArtUnknownName => "TZ-ART001",
            Code::ArtSlotMismatch => "TZ-ART002",
            Code::ArtUnreferenced => "TZ-ART003",
            Code::ArtForwardForm => "TZ-ART004",
            Code::AllowlistStale => "TZ-ALLOW001",
            Code::ObsClock => "TZ-OBS001",
            Code::TuneFormLiteral => "TZ-TUNE001",
            Code::IoRawWrite => "TZ-IO001",
        }
    }

    pub const ALL: [Code; 15] = [
        Code::RngAmbient,
        Code::RngWallClock,
        Code::RngTimeSeed,
        Code::DetHashOrder,
        Code::DetPartialSort,
        Code::PanicHotPath,
        Code::IndexHotPath,
        Code::ArtUnknownName,
        Code::ArtSlotMismatch,
        Code::ArtUnreferenced,
        Code::ArtForwardForm,
        Code::AllowlistStale,
        Code::ObsClock,
        Code::TuneFormLiteral,
        Code::IoRawWrite,
    ];
}

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Finding {
    pub code: Code,
    pub file: String,
    pub line: u32,
    pub message: String,
    /// set by the allowlist pass; allowlisted findings never fail the run
    pub allowlisted: bool,
}

impl Finding {
    pub fn new(code: Code, file: &str, line: u32, message: String) -> Finding {
        Finding { code, file, line, message, allowlisted: false }
    }
}

/// Render findings as compiler-style text lines.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        let suffix = if f.allowlisted { "  [allowlisted]" } else { "" };
        let _ = writeln!(out, "{}: {}:{}: {}{}", f.code.as_str(), f.file, f.line,
                         f.message, suffix);
    }
    out
}

/// Render the machine-readable report (see docs/invariants.md#report).
pub fn render_json(findings: &[Finding], mode: &str, deny_all: bool) -> String {
    let active = findings.iter().filter(|f| !f.allowlisted).count();
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, " \"tool\": \"tezo-lint\",");
    let _ = writeln!(out, " \"version\": {},", json_str(env!("CARGO_PKG_VERSION")));
    let _ = writeln!(out, " \"mode\": {},", json_str(mode));
    let _ = writeln!(out, " \"deny_all\": {},", deny_all);
    let _ = writeln!(out, " \"clean\": {},", active == 0);
    out.push_str(" \"counts\": {\n");
    for (i, code) in Code::ALL.iter().enumerate() {
        let n = findings.iter().filter(|f| f.code == *code).count();
        let comma = if i + 1 == Code::ALL.len() { "" } else { "," };
        let _ = writeln!(out, "  {}: {}{}", json_str(code.as_str()), n, comma);
    }
    out.push_str(" },\n");
    out.push_str(" \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        let comma = if i + 1 == findings.len() { "" } else { "," };
        out.push_str("\n  {");
        let _ = write!(out, "\"code\": {}, ", json_str(f.code.as_str()));
        let _ = write!(out, "\"file\": {}, ", json_str(&f.file));
        let _ = write!(out, "\"line\": {}, ", f.line);
        let _ = write!(out, "\"allowlisted\": {}, ", f.allowlisted);
        let _ = write!(out, "\"message\": {}", json_str(&f.message));
        out.push('}');
        out.push_str(comma);
    }
    out.push_str("\n ]\n}\n");
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique() {
        let mut names: Vec<&str> = Code::ALL.iter().map(|c| c.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Code::ALL.len());
    }

    #[test]
    fn json_report_shape() {
        let fs = vec![
            Finding::new(Code::PanicHotPath, "a.rs", 3, "x.unwrap()".into()),
            Finding {
                allowlisted: true,
                ..Finding::new(Code::IndexHotPath, "b.rs", 9, "v[\"k\"]".into())
            },
        ];
        let json = render_json(&fs, "code", true);
        assert!(json.contains("\"TZ-PANIC001\": 1"));
        assert!(json.contains("\"clean\": false"));
        assert!(json.contains("\\\"k\\\""));
        // one active finding: PanicHotPath (the other is allowlisted)
        let clean = render_json(&fs[1..], "code", true);
        assert!(clean.contains("\"clean\": true"));
    }
}
